"""Pipeline parallelism: pipelined execution ≡ sequential stage stack."""

import os

import pytest

# a local 4-device CPU mesh for the pipeline test only (this module must
# be imported before jax initializes — pytest imports it fresh per file,
# but other test modules may have initialized jax already, so spawn a
# subprocess to guarantee the device count)
import subprocess
import sys


def test_pipeline_matches_sequential():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import run_pipeline, bubble_fraction

mesh = jax.make_mesh((4,), ("stage",))
S, M, B, D = 4, 8, 2, 16
rng = np.random.default_rng(0)
w = jnp.asarray(rng.standard_normal((S, D, D)) * 0.3, jnp.float32)
x = jnp.asarray(rng.standard_normal((M, B, D)), jnp.float32)

def stage_fn(params, x):
    return jnp.tanh(x @ params[0][0])  # [0]: this stage's (1,D,D) slice

out = run_pipeline(mesh, stage_fn, (w,), x, n_stages=S, n_micro=M)

# sequential reference
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ w[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                           atol=2e-4)
assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
print("PIPELINE_OK")
"""
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=300,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "PIPELINE_OK" in proc.stdout, proc.stderr[-2000:]
