"""End-to-end behaviour tests: optimize → execute → observe the speedup,
GSN pipeline, and the full training loop with checkpoint-resume."""

import time

import numpy as np
import pytest

from repro.core import fgh, verify
from repro.core.program import run_program
from repro.datalog import datasets, programs
from helpers import values_close


def test_quickstart_cc_speedup():
    """Fig. 1 end-to-end: synthesize H for CC, run both, same answer, and
    the optimized program touches O(n) state instead of O(n²)."""
    b = programs.cc()
    task = verify.task_from_program(b.original, ["E", "V"])
    rep = fgh.optimize(task, rng=np.random.default_rng(0))
    assert rep.ok and rep.method == "rule"

    g = datasets.powerlaw(400, m_attach=3, seed=0)
    db = b.make_db(g)

    t0 = time.perf_counter()
    o, s_orig = run_program(b.original, db)
    t_orig = time.perf_counter() - t0
    t0 = time.perf_counter()
    p, s_opt = run_program(rep.program, db)
    t_opt = time.perf_counter() - t0
    assert values_close(np.asarray(o), np.asarray(p))
    # O(n²) TC state vs O(n) label vector: the optimized form must win
    # decisively on a 400-node graph (paper reports 1-4 orders)
    assert t_opt < t_orig, (t_orig, t_opt)


def test_invariant_report_matches_paper_fig10():
    """Fig. 10: BM/R/MLM need invariants (R/MLM via Γ-constrained
    verification in our system), CC/SSSP don't."""
    from repro.core import invariants as inv_mod
    b = programs.bm()
    task = verify.task_from_program(b.original, ["E", "V"])
    invs, stats = inv_mod.infer_invariants(task)
    assert len(invs) >= 1
    assert stats["time_s"] < 30


@pytest.mark.slow  # full training loop with checkpoint round-trip
def test_train_loop_learns_and_resumes(tmp_path):
    from repro.launch.train import train
    # phase 1: train 30 steps with checkpointing
    _, losses1 = train("xlstm-125m", steps=30, batch=4, seq=64,
                       ckpt_dir=str(tmp_path), log_every=100)
    assert np.isfinite(losses1).all()
    # loss must have moved down on the structured synthetic stream
    assert min(losses1[-5:]) < losses1[0]
    # phase 2: resume — continues from step >0 (fewer new steps run)
    _, losses2 = train("xlstm-125m", steps=40, batch=4, seq=64,
                       ckpt_dir=str(tmp_path), log_every=100)
    assert len(losses2) <= 40 - 25  # resumed near step 30


def test_serving_loop_emits_tokens():
    from repro.launch.serve import Request, serve_batch
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, 500, 16, dtype=np.int32), max_new=8)
            for _ in range(3)]
    stats = serve_batch("minicpm-2b", reqs, smoke=True, t_max=64)
    assert all(len(r.out) == 8 for r in reqs)
    assert stats["tok_per_s"] > 0


def test_gsn_speedup_mechanics():
    """GSN converges to the same fixpoint with a Δ-driven loop."""
    b = programs.sssp(a=0, wmax=4, dmax=32)
    g = datasets.erdos_renyi(24, 2.5, seed=1, weighted=True, wmax=4)
    db = b.make_db(g)
    task = verify.task_from_program(b.original, ["E3"])
    rep = fgh.optimize(task, rng=np.random.default_rng(0))
    assert rep.ok
    nav, _ = run_program(rep.program, db, mode="naive")
    gsn, _ = run_program(rep.program, db, mode="seminaive")
    assert values_close(np.asarray(nav), np.asarray(gsn))
