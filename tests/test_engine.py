"""Engine vs brute-force oracle: the contraction planner must agree with
full variable-assignment enumeration on random queries (hypothesis)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal deterministic shim (see helpers.py)
    from helpers import given, settings, strategies as st

from repro.core import engine, ir
from repro.core import semiring as sr_mod
from helpers import brute_force_eval, values_close


def _schema():
    s = ir.Schema()
    s.declare("E", ("id", "id"), "bool")
    s.declare("V", ("id",), "bool")
    s.declare("W", ("id", "id"), "trop")
    s.declare("Nt", ("id",), "nat")
    return s


def _db(rng, n=3):
    s = _schema()
    w = rng.integers(0, 3, (n, n)).astype(np.float32)
    w[rng.random((n, n)) > 0.5] = np.inf
    return engine.Database(s, {"id": n}, {
        "E": rng.random((n, n)) < 0.5,
        "V": rng.random(n) < 0.7,
        "W": w,
        "Nt": rng.integers(0, 3, n).astype(np.float32),
    })


VARS = ["x", "y", "z", "u"]


def _atoms_strategy(sr_name):
    var = st.sampled_from(VARS)
    arg = st.one_of(var, st.builds(ir.C, st.integers(0, 2)))
    rel2 = st.builds(lambda a, b: ir.RelAtom("E", (a, b), cast=sr_name != "bool"),
                     arg, arg)
    rel1 = st.builds(lambda a: ir.RelAtom("V", (a,), cast=sr_name != "bool"),
                     arg)
    pred = st.builds(lambda p, a, b: ir.PredAtom(p, (a, b)),
                     st.sampled_from(["eq", "neq", "lt"]), arg, arg)
    opts = [rel2, rel1, pred]
    if sr_name == "trop":
        opts.append(st.builds(lambda a, b: ir.RelAtom("W", (a, b)), arg, arg))
    if sr_name != "bool":
        opts.append(st.builds(ir.ValAtom, var))
        opts.append(st.builds(ir.ConstAtom,
                              st.sampled_from([0.0, 1.0, 2.0])))
    if sr_name == "nat":
        opts.append(st.builds(lambda a: ir.RelAtom("Nt", (a,)), arg))
    return st.one_of(*opts)


@pytest.mark.parametrize("sr_name", ["bool", "trop", "nat", "maxplus"])
@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_engine_matches_bruteforce(sr_name, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
    db = _db(rng)
    n_terms = data.draw(st.integers(1, 3))
    terms = []
    for _ in range(n_terms):
        atoms = data.draw(st.lists(_atoms_strategy(sr_name), min_size=1,
                                   max_size=3))
        used = set()
        for a in atoms:
            used.update(ir.atom_vars(a))
        head = tuple(v for v in VARS[:2] if v in used) or ("x",)
        bound = tuple(sorted(used - set(head)))
        terms.append(ir.Term(tuple(atoms), bound))
    head = tuple(sorted(set().union(*[t.free_vars() for t in terms])
                        & {"x", "y"})) or ("x",)
    # rebuild terms so every non-head var is bound
    terms = [ir.Term(t.atoms, tuple(sorted(t.vars() - set(head))))
             for t in terms]
    e = ir.SSP(head, tuple(terms), sr_name)
    try:
        got = engine.eval_ssp(e, db, backend="np")
    except ValueError:
        return  # dangling bound var under non-idempotent ⊕: rejected by design
    want = brute_force_eval(e, db)
    assert values_close(got, want), (ir.ssp_str(e), got, want)


@pytest.mark.parametrize("sr_name", ["bool", "trop", "nat"])
def test_normalize_preserves_semantics(sr_name):
    rng = np.random.default_rng(0)
    db = _db(rng)
    t = ir.Term((ir.RelAtom("E", ("x", "z"), cast=sr_name != "bool"),
                 ir.PredAtom("eq", ("z", "y")),
                 ir.RelAtom("V", ("y",), cast=sr_name != "bool")),
                ("z",))
    e = ir.SSP(("x", "y"), (t,), sr_name)
    n = ir.normalize(e)
    assert values_close(engine.eval_ssp(e, db, backend="np"),
                        engine.eval_ssp(n, db, backend="np"))
    # eq-elimination actually fired (axiom 25)
    assert all("eq" not in str(a) or "z" not in str(a)
               for t2 in n.terms for a in t2.atoms)


def test_matmul_path_vs_bruteforce():
    rng = np.random.default_rng(1)
    db = _db(rng, n=4)
    # boolean join: classic composition E∘E
    t = ir.Term((ir.RelAtom("E", ("x", "z")), ir.RelAtom("E", ("z", "y"))),
                ("z",))
    e = ir.SSP(("x", "y"), (t,), "bool")
    assert values_close(engine.eval_ssp(e, db, backend="np"),
                        brute_force_eval(e, db))
    # tropical min-plus composition
    t2 = ir.Term((ir.RelAtom("W", ("x", "z")), ir.RelAtom("W", ("z", "y"))),
                 ("z",))
    e2 = ir.SSP(("x", "y"), (t2,), "trop")
    assert values_close(engine.eval_ssp(e2, db, backend="np"),
                        brute_force_eval(e2, db))


def test_jnp_backend_agrees_with_np():
    rng = np.random.default_rng(2)
    db = _db(rng)
    t = ir.Term((ir.RelAtom("E", ("x", "z")), ir.RelAtom("E", ("z", "y"))),
                ("z",))
    e = ir.SSP(("x", "y"), (t,), "bool")
    a = engine.eval_ssp(e, db, backend="np")
    b = engine.eval_ssp(e, db, backend="jnp")
    assert values_close(a, np.asarray(b))
