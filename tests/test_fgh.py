"""End-to-end FGH optimizer: synthesis, verification soundness, Π₁ ≡ Π₂."""

import numpy as np
import pytest

from repro.core import fgh, ir, verify
from repro.core.program import run_program
from repro.datalog import datasets, programs
from helpers import values_close

CASES = {
    "CC": (programs.cc, ["E", "V"], "rule"),
    "BM": (programs.bm, ["E", "V"], "rule"),
    "SSSP": (programs.sssp, ["E3"], "rule"),
    "WS": (programs.ws, ["A2"], "cegis"),
    "MLM": (programs.mlm, ["E", "V"], "cegis"),
    "R": (programs.radius, ["E", "V"], "cegis"),
    "APSP100": (programs.apsp100, ["Ew"], "cegis"),
}


def _dataset_for(name):
    if name in ("MLM", "R"):
        return datasets.random_recursive_tree(25, seed=3)
    if name == "WS":
        return datasets.vector_data(20, seed=0, vmax=6)
    if name in ("SSSP", "APSP100"):
        return datasets.erdos_renyi(20, 2.0, seed=4, weighted=True, wmax=4)
    return datasets.erdos_renyi(20, 2.0, seed=4)


@pytest.mark.parametrize("name", list(CASES))
def test_fgh_synthesizes_and_matches(name):
    mk, edbs, expected_method = CASES[name]
    b = mk()
    task = verify.task_from_program(b.original, edbs,
                                    constraint=b.constraint)
    rep = fgh.optimize(task, rng=np.random.default_rng(0))
    assert rep.ok, (name, rep.stats)
    assert rep.method == expected_method, (name, rep.method, rep.stats)
    db = b.make_db(_dataset_for(name))
    o, _ = run_program(b.original, db)
    if b.original.post is not None:
        rep.program.post = b.original.post
    p, _ = run_program(rep.program, db)
    assert values_close(np.asarray(o), np.asarray(p)), name


def test_synthesized_matches_published_h():
    """The synthesized H for CC is isomorphic to the paper's Fig. 1(b)."""
    b = programs.cc()
    task = verify.task_from_program(b.original, ["E", "V"])
    rep = fgh.optimize(task, rng=np.random.default_rng(0))
    published = b.optimized.strata[0].rules["CC"].body
    assert ir.isomorphic(rep.h_body, published), ir.ssp_str(rep.h_body)


def test_verifier_rejects_wrong_h():
    """Soundness: a subtly wrong H must produce a counterexample."""
    b = programs.cc()
    task = verify.task_from_program(b.original, ["E", "V"])
    # wrong: drops the min with the node's own label
    bad = ir.SSP(("x",), (
        ir.Term((ir.RelAtom("CC", ("y",)),
                 ir.RelAtom("E", ("x", "y"), cast=True)), ("y",)),
    ), "trop")
    res = verify.verify_h(task, bad, rng=np.random.default_rng(0))
    assert not res.ok
    assert res.counterexample is not None


def test_verifier_accepts_published_h():
    for name, (mk, edbs, _) in CASES.items():
        b = mk()
        if not b.optimized.strata:
            continue
        task = verify.task_from_program(b.original, edbs,
                                        constraint=b.constraint)
        h = next(iter(b.optimized.strata[0].rules.values())).body
        res = verify.verify_h(task, h, rng=np.random.default_rng(1))
        assert res.ok, (name, res.counterexample)


def test_bm_requires_invariant():
    """Without the commutation invariant, BM's rule-based synthesis fails
    (Example 3.8: P₁ ≠ H(G) for arbitrary TC) — with it, it succeeds."""
    b = programs.bm()
    task = verify.task_from_program(b.original, ["E", "V"])
    h_no_inv, _ = fgh.rule_based_synthesis(task, [])
    assert h_no_inv is None
    from repro.core import invariants as inv_mod
    invs, _ = inv_mod.infer_invariants(task, rng=np.random.default_rng(0))
    assert invs, "commutation invariant not mined"
    h, _ = fgh.rule_based_synthesis(task, invs)
    assert h is not None


def test_gh_program_iterates_fewer_or_equal(
        ):
    """Corollary 3.2: the GH-program converges at least as fast."""
    g = datasets.erdos_renyi(30, 2.0, seed=9)
    b = programs.cc()
    db = b.make_db(g)
    _, s1 = run_program(b.original, db)
    _, s2 = run_program(b.optimized, db)
    assert s2.iterations[0] <= s1.iterations[0] + 1


def test_simple_magic_needs_no_invariant():
    """Example 3.5 vs 3.8: the left-recursive (simple magic) program
    rewrites by plain denormalization — no invariant required — while the
    right-recursive BM does (test_bm_requires_invariant)."""
    b = programs.simple_magic()
    task = verify.task_from_program(b.original, ["E", "V"])
    h, stats = fgh.rule_based_synthesis(task, [])  # NO invariants supplied
    assert h is not None, stats
    res = verify.verify_h(task, h, rng=np.random.default_rng(0))
    assert res.ok
    db = b.make_db(datasets.erdos_renyi(25, 2.0, seed=11))
    o, _ = run_program(b.original, db)
    prog = fgh.make_gh_program(task, h)
    p, _ = run_program(prog, db)
    assert values_close(np.asarray(o), np.asarray(p))
