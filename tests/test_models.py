"""Per-architecture smoke tests + decode/forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T

ARCHS = configs.list_archs()
KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=24):
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32),
             "labels": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)}
    if cfg.family == "vlm":
        batch["embeds"] = rng.standard_normal(
            (b, 8, cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        batch["enc_embeds"] = rng.standard_normal(
            (b, 16, cfg.d_model)).astype(np.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward/loss on CPU; shapes + no NaNs."""
    cfg = configs.get(arch, smoke=True)
    params, specs = T.init_params(cfg, KEY, jnp.float32)
    batch = _batch(cfg)
    logits, aux, _ = T.forward(params, cfg, batch.get("tokens"),
                               embeds=batch.get("embeds"),
                               enc_embeds=batch.get("enc_embeds"))
    b = batch["tokens"].shape[0]
    exp_t = batch["tokens"].shape[1] + (8 if cfg.family == "vlm" else 0)
    assert logits.shape == (b, exp_t, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, (ce, aux) = T.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    # one gradient step decreases nothing catastrophic (finite grads)
    g = jax.grad(lambda p: T.loss_fn(p, cfg, batch)[0])(params)
    assert all(np.isfinite(np.asarray(x, np.float32)).all()
               for x in jax.tree.leaves(g))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    """prefill+decode logits must equal full-sequence forward logits."""
    cfg = configs.get(arch, smoke=True)
    params, _ = T.init_params(cfg, KEY, jnp.float32)
    rng = np.random.default_rng(1)
    b, s = 2, 12
    toks = rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embeds"] = rng.standard_normal(
            (b, 16, cfg.d_model)).astype(np.float32)
    full_logits, _, _ = T.forward(params, cfg, toks, **kw)

    cache = T.init_cache(cfg, b, 32, jnp.float32)
    logits_p, _, cache = T.forward(params, cfg, toks[:, :s - 2],
                                   cache=cache, **kw)
    l1, cache = T.decode_step(params, cfg, toks[:, s - 2:s - 1], cache)
    l2, cache = T.decode_step(params, cfg, toks[:, s - 1:s], cache)
    np.testing.assert_allclose(np.asarray(l1[:, 0]),
                               np.asarray(full_logits[:, s - 2]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(l2[:, 0]),
                               np.asarray(full_logits[:, s - 1]),
                               rtol=2e-3, atol=2e-3)


def test_window_and_chunk_masks_differ_from_full():
    cfg_w = configs.get("starcoder2-7b", smoke=True)
    params, _ = T.init_params(cfg_w, KEY, jnp.float32)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg_w.vocab, (1, 100)).astype(np.int32)
    lw, _, _ = T.forward(params, cfg_w, toks)
    # same params, window disabled → different logits at long range
    import dataclasses
    cfg_full = dataclasses.replace(cfg_w, window=None)
    lf, _, _ = T.forward(params, cfg_full, toks)
    assert not np.allclose(np.asarray(lw[:, -1]), np.asarray(lf[:, -1]),
                           atol=1e-4)


def test_moe_capacity_drop_and_balance():
    cfg = configs.get("deepseek-moe-16b", smoke=True)
    from repro.models import moe as moe_mod
    p, _ = moe_mod.moe_init(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    y, aux = moe_mod.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0  # load-balance loss is live


def test_param_counts_match_published():
    # ±10% of the published sizes (architectural approximations documented
    # in DESIGN.md)
    expect = {"llama3-405b": 405e9, "mistral-large-123b": 123e9,
              "deepseek-moe-16b": 16.4e9, "minicpm-2b": 2.7e9,
              "starcoder2-7b": 7.2e9, "llava-next-mistral-7b": 7.2e9,
              "whisper-base": 0.085e9, "xlstm-125m": 0.125e9}
    for arch, want in expect.items():
        got = configs.get(arch).param_count()
        assert abs(got - want) / want < 0.16, (arch, got, want)
