"""IR-level properties: normalization, substitution, canonical forms."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal deterministic shim (see helpers.py)
    from helpers import given, settings, strategies as st

from repro.core import engine, ir
from repro.core.ir import C, ConstAtom, PredAtom, RelAtom, Term, ValAtom
from helpers import brute_force_eval, values_close


def _schema():
    s = ir.Schema()
    s.declare("E", ("id", "id"), "bool")
    s.declare("V", ("id",), "bool")
    s.declare("X", ("id", "id"), "bool")
    return s


def test_substitution_equals_numeric_composition():
    """substitute_defs(G, {X: F}) must evaluate like eval(G) ∘ eval(F)."""
    rng = np.random.default_rng(0)
    s = _schema()
    db0 = engine.Database(s, {"id": 4}, {
        "E": rng.random((4, 4)) < 0.5, "V": rng.random(4) < 0.8,
        "X": rng.random((4, 4)) < 0.4})
    f = ir.SSP(("x", "y"), (
        Term((RelAtom("V", ("x",)), PredAtom("eq", ("x", "y"))), ()),
        Term((RelAtom("E", ("x", "z")), RelAtom("X", ("z", "y"))), ("z",)),
    ), "bool")
    g = ir.SSP(("y",), (Term((RelAtom("X", (C(0), "y")),), ()),), "bool")
    composed = ir.substitute_defs(g, {"X": f})
    fx = engine.eval_ssp(f, db0, backend="np")
    direct = engine.eval_ssp(g, db0.with_relations({"X": fx}), backend="np")
    via_sub = engine.eval_ssp(composed, db0, backend="np")
    assert values_close(direct, via_sub)


def test_cast_substitution_idempotent_semiring():
    rng = np.random.default_rng(1)
    s = _schema()
    db0 = engine.Database(s, {"id": 3}, {
        "E": rng.random((3, 3)) < 0.5, "V": rng.random(3) < 0.8,
        "X": rng.random((3, 3)) < 0.4})
    f = ir.SSP(("x", "y"), (
        Term((RelAtom("E", ("x", "z")), RelAtom("X", ("z", "y"))), ("z",)),
    ), "bool")
    g = ir.SSP(("x",), (
        Term((ValAtom("v"), RelAtom("X", ("x", "v"), cast=True)), ("v",)),
    ), "trop")
    composed = ir.substitute_defs(g, {"X": f})
    fx = engine.eval_ssp(f, db0, backend="np")
    direct = engine.eval_ssp(g, db0.with_relations({"X": fx}), backend="np")
    via_sub = engine.eval_ssp(composed, db0, backend="np")
    assert values_close(direct, via_sub)


def test_cast_substitution_refuses_nonidempotent():
    f = ir.SSP(("x", "y"), (
        Term((RelAtom("E", ("x", "z")), RelAtom("X", ("z", "y"))), ("z",)),
    ), "bool")
    g = ir.SSP(("x",), (
        Term((ValAtom("v"), RelAtom("X", ("x", "v"), cast=True)), ("v",)),
    ), "nat")
    with pytest.raises(ir.NonIdempotentCast):
        ir.substitute_defs(g, {"X": f})


def test_isomorphism_bound_var_renaming():
    t1 = ir.SSP(("x",), (Term((RelAtom("E", ("x", "a")),
                               RelAtom("E", ("a", "b"))), ("a", "b")),),
                "bool")
    t2 = ir.SSP(("x",), (Term((RelAtom("E", ("p", "q")),
                               RelAtom("E", ("x", "p"))), ("q", "p")),),
                "bool")
    assert ir.isomorphic(t1, t2)
    t3 = ir.SSP(("x",), (Term((RelAtom("E", ("x", "a")),
                               RelAtom("E", ("b", "a"))), ("a", "b")),),
                "bool")
    assert not ir.isomorphic(t1, t3)


def test_eq_elimination_with_constant():
    t = Term((RelAtom("E", ("x", "z")), PredAtom("eq", ("z", C(1)))), ("z",))
    n = ir.normalize_term(t, "bool")
    assert n is not None
    assert n.atoms[0].args == ("x", C(1))
    assert not n.bound


def test_value_arithmetic_fold_trop():
    """⊕_d val(d)⊗[d=d1+d2] = val(d1)⊗val(d2) in (min,+) (Sec. 5 axioms)."""
    t = Term((ValAtom("d"), PredAtom("sum3", ("d", "d1", "d2"))), ("d",))
    n = ir.normalize_term(t, "trop")
    kinds = sorted(type(a).__name__ for a in n.atoms)
    assert kinds == ["ValAtom", "ValAtom"]
    # and NOT in ℕ (⊗ is ×, the fold would be unsound)
    n2 = ir.normalize_term(t, "nat")
    assert any(isinstance(a, PredAtom) for a in n2.atoms)


def test_contradiction_kills_term():
    t = Term((RelAtom("E", ("x", "y")), PredAtom("neq", ("x", "x"))), ())
    assert ir.normalize_term(t, "bool") is None


def test_canonical_ssp_dedups_idempotent_terms():
    t1 = Term((RelAtom("E", ("x", "a")),), ("a",))
    t2 = Term((RelAtom("E", ("x", "b")),), ("b",))
    e = ir.SSP(("x",), (t1, t2), "bool")
    assert len(ir.normalize(e).terms) == 1
