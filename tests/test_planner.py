"""The cost-based execution planner (DESIGN.md §4): golden explain()
renderings, forced-mode ≡ auto answer parity across semirings, stable
plan-cache fingerprints (the id()-reuse fix), cache-hit construction
hoisting, and scale/serve routing."""

import gc
import re

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine, planner
from repro.core import program as prog_mod
from repro.core.program import run_program
from repro.datalog import datasets, programs
from repro.sparse.coo import SparseRelation

CPU = jax.default_backend() == "cpu"


def _bm_db(n=120, avg_deg=3.0, seed=2, sparse=False):
    g = datasets.erdos_renyi(n, avg_deg, seed=seed)
    schema = programs.bm(a=0).original.schema
    e = g.sparse_adjacency() if sparse else g.adjacency()
    return engine.Database(schema, {"id": n},
                           {"E": e, "V": jnp.ones((n,), bool)})


def _norm(text: str) -> str:
    """Blank out the 16-hex signature so goldens survive hash changes."""
    return re.sub(r"signature=[0-9a-f]{16}", "signature=<sig>", text)


# --------------------------------------------------------------------------
# Golden explain() output (satellite: planner decision coverage)
# --------------------------------------------------------------------------


@pytest.mark.skipif(not CPU, reason="golden plans assume the CPU backend")
def test_explain_golden_bm():
    db = _bm_db(n=120, avg_deg=3.0, seed=2)
    plan = planner.plan_program(programs.bm(a=0).optimized, db)
    assert _norm(planner.explain(plan)) == """\
plan BM_opt  mode=auto  objective=latency  signature=<sig>
  stratum 0  runner=sparse_frontier  idbs=Q
    reason      min est. total flops among 4 feasible candidates (cpu host ⇒ frontier worklist)
    storage     E: dense→sparse (density 0.0257 < 0.05)
    cost        194 flops/iter × 5 iters  [analytic]
    considered  sparse_frontier=970  dense_gsn=2.45e+03  sparse_jit=2.45e+03  dense_naive=3.05e+03
    rejected    sparse_frontier_pallas: fused-kernel SpMM is a batched-serving backend (objective='throughput') — single-shot latency keeps the worklist/staged runners
    rejected    vector_dense: linear operator is sparse — the SpMV/SpMM runners cover it
  outputs    Qans"""


@pytest.mark.skipif(not CPU, reason="golden plans assume the CPU backend")
def test_explain_golden_cc_dense():
    b = programs.cc()
    g = datasets.erdos_renyi(40, 14.0, seed=1)
    plan = planner.plan_program(b.optimized, b.make_db(g))
    assert _norm(planner.explain(plan)) == """\
plan CC_opt  mode=auto  objective=latency  signature=<sig>
  stratum 0  runner=vector_dense  idbs=CC
    reason      min est. total flops among 3 feasible candidates
    cost        1.64e+03 flops/iter × 3 iters  [analytic]
    considered  dense_gsn=4.92e+03  vector_dense=4.92e+03  dense_naive=5.04e+03
    rejected    sparse_frontier: linear operator materializes dense (no sparse binary EDB fast path)
    rejected    sparse_frontier_pallas: fused-kernel SpMM is a batched-serving backend (objective='throughput') — single-shot latency keeps the worklist/staged runners
    rejected    sparse_jit: linear operator materializes dense (no sparse binary EDB fast path)
  outputs    CCans"""


@pytest.mark.skipif(not CPU, reason="golden plans assume the CPU backend")
def test_explain_golden_sssp():
    b = programs.sssp(a=0, wmax=4, dmax=40)
    g = datasets.erdos_renyi(60, 2.5, seed=4, weighted=True, wmax=4)
    plan = planner.plan_program(b.optimized, b.make_db(g))
    text = _norm(planner.explain(plan))
    assert "runner=vector_dense" in text
    # the dense value-domain join (n·n·w) must price above the n² matvec
    sp = plan.strata[0]
    assert sp.considered["dense_gsn"].total > \
        sp.considered["vector_dense"].total
    assert "outputs    SPans" in text


def test_explain_forced_plan():
    db = _bm_db()
    plan = planner.plan_program(programs.bm(a=0).optimized, db,
                                mode="seminaive")
    assert plan.strata[0].runner == "dense_gsn"
    assert plan.strata[0].storage == {}  # forced plans never re-home
    assert "forced by mode='dense_gsn'" in planner.explain(plan)


# --------------------------------------------------------------------------
# Forced-mode plans agree with mode="auto" across semirings
# --------------------------------------------------------------------------


@pytest.mark.parametrize("bench,db", [
    ("bm", None), ("cc", None), ("sssp", None), ("radius", None),
    ("ws", None), ("mlm", None),
])
def test_auto_matches_forced_modes(bench, db):
    """Π₂ answers must be identical under auto and every feasible forced
    runner — bool, trop, maxplus, nat all covered."""
    if bench == "ws":
        b = programs.ws()
        db = b.make_db(datasets.vector_data(40, seed=1))
    elif bench in ("radius", "mlm"):
        b = getattr(programs, bench)()
        db = b.make_db(datasets.random_recursive_tree(30, seed=3))
    elif bench == "sssp":
        b = programs.sssp(a=0, wmax=4, dmax=40)
        db = b.make_db(datasets.erdos_renyi(48, 2.5, seed=4, weighted=True,
                                            wmax=4))
    else:
        b = getattr(programs, bench)()
        db = b.make_db(datasets.erdos_renyi(48, 3.0, seed=7))
    ref, _ = run_program(b.optimized, db, mode="naive")
    got, stats = run_program(b.optimized, db, mode="auto")
    assert np.array_equal(np.asarray(ref), np.asarray(got)), \
        stats.plan.strata[0].runner
    sp = stats.plan.strata[0]
    for runner in sp.considered:
        forced, _ = run_program(b.optimized, db, mode=runner)
        assert np.array_equal(np.asarray(ref), np.asarray(forced)), runner


def test_originals_match_under_auto():
    """Auto planning of the *original* Π₁ programs (multi-term strata,
    value domains, output chains) changes nothing about the answers."""
    g = datasets.erdos_renyi(24, 2.5, seed=6)
    for mk in (programs.bm, programs.cc, programs.mlm):
        b = mk()
        db = b.make_db(g if mk is not programs.mlm
                       else datasets.random_recursive_tree(24, seed=6))
        ref, _ = run_program(b.original, db, mode="naive")
        got, _ = run_program(b.original, db, mode="auto")
        assert np.array_equal(np.asarray(ref), np.asarray(got)), b.name


def test_nat_semiring_falls_back_to_naive():
    """No ⊖ in ℕ: GSN and the vector runners must be rejected."""
    b = programs.mlm()
    db = b.make_db(datasets.random_recursive_tree(20, seed=1))
    plan = planner.plan_program(b.optimized, db)
    sp = plan.strata[0]
    assert sp.runner == "dense_naive"
    assert "lacks ⊖" in sp.rejected["dense_gsn"]
    assert "lacks ⊖" in sp.rejected["sparse_jit"]


# --------------------------------------------------------------------------
# Stable fingerprints (satellite: the id()-reuse plan-cache key fix)
# --------------------------------------------------------------------------


def test_fingerprint_token_is_not_recycled():
    """A dead array's token is evicted, so a new array landing on the
    same id() can never alias its cache entry (the id(v) bug)."""
    a = np.zeros((8, 8), np.float32)
    tok_a = planner._token(a)
    key = id(a)
    assert key in planner._fp_tokens
    del a
    gc.collect()
    assert key not in planner._fp_tokens  # weakref callback evicted it
    b = np.zeros((8, 8), np.float32)
    assert planner._token(b) != tok_a


def test_fingerprint_distinguishes_same_shape_arrays():
    a = jnp.zeros((4,))
    b = jnp.zeros((4,))
    assert planner.value_fingerprint(a) != planner.value_fingerprint(b)
    assert planner.value_fingerprint(a) == planner.value_fingerprint(a)
    s = SparseRelation.from_dense(np.eye(3, dtype=bool), "bool")
    assert planner.value_fingerprint(s) == planner.value_fingerprint(s)
    assert planner.value_fingerprint(s) != planner.value_fingerprint(
        SparseRelation.from_dense(np.eye(3, dtype=bool), "bool"))


def test_multi_stratum_cache_sees_prior_stratum_outputs():
    """Regression: a later stratum whose rules read only earlier-stratum
    IDBs (BC's Lv reads only R3) must still fingerprint those inputs —
    the verifier's one-program/many-databases pattern."""
    b = programs.bc(dmax=8)
    g1 = datasets.erdos_renyi(6, 1.5, seed=0)
    g2 = datasets.erdos_renyi(6, 1.5, seed=11)
    db1, db2 = b.make_db(g1), b.make_db(g2)
    a1, _ = run_program(b.original, db1, mode="naive")
    a2, _ = run_program(b.original, db2, mode="naive")  # same Program obj
    fresh2, _ = run_program(programs.bc(dmax=8).original, db2,
                            mode="naive")
    assert np.array_equal(np.asarray(a2), np.asarray(fresh2))
    assert not np.array_equal(np.asarray(a1), np.asarray(a2))


def test_domain_sizes_are_part_of_the_fingerprint():
    """Regression: two databases sharing the same relation arrays but
    differing in a sort domain (SSSP's value domain d) must not share
    staged fixpoints — domain sizes are baked into staged shapes."""
    b = programs.sssp(a=0, wmax=4, dmax=6)
    g = datasets.path_graph(10)
    db_small = b.make_db(g)                       # d domain = 6
    db_big = engine.Database(db_small.schema,
                             {**db_small.domains, "d": 40},
                             db_small.relations)  # same arrays, bigger d
    a_small, _ = run_program(b.original, db_small, mode="naive")
    a_big, _ = run_program(b.original, db_big, mode="naive")
    fresh = programs.sssp(a=0, wmax=4, dmax=6)
    ref_big, _ = run_program(fresh.original,
                             engine.Database(db_small.schema,
                                             {**db_small.domains, "d": 40},
                                             db_small.relations),
                             mode="naive")
    assert np.array_equal(np.asarray(a_big), np.asarray(ref_big))
    assert not np.array_equal(np.asarray(a_small), np.asarray(a_big))


def test_plans_with_different_edge_overrides_do_not_share_cache():
    """Regression: two plans for the same Program/db differing only in
    their ``edges=`` override (the serve-loop SSSP pattern, where E
    arrives solely via the override) must not share staged fixpoints."""
    b = programs.sssp(a=0, wmax=4, dmax=40)
    db = engine.Database(b.original.schema, {"id": 60, "w": 4, "d": 40}, {})
    g1 = datasets.erdos_renyi(60, 2.5, seed=4, weighted=True, wmax=4)
    g2 = datasets.erdos_renyi(60, 2.5, seed=8, weighted=True, wmax=4)
    p1 = planner.plan_program(b.optimized, db,
                              edges=g1.sparse_adjacency(semiring="trop"))
    p2 = planner.plan_program(b.optimized, db,
                              edges=g2.sparse_adjacency(semiring="trop"))
    a1, _ = run_program(b.optimized, db, plan=p1)
    a2, _ = run_program(b.optimized, db, plan=p2)
    ref2, _ = run_program(b.optimized, b.make_db(g2), mode="naive")
    assert np.array_equal(np.asarray(a2), np.asarray(ref2))
    assert not np.array_equal(np.asarray(a1), np.asarray(a2))


def test_edges_override_is_always_honored():
    """An ``edges=`` override must force a vector runner — a dense
    engine pick would silently run over the stored relations instead."""
    b = programs.bm(a=0)
    db = _bm_db(n=40, seed=1)
    g2 = datasets.erdos_renyi(40, 3.0, seed=9)
    plan = planner.plan_program(b.optimized, db,
                                edges=g2.adjacency().astype(bool))
    assert plan.strata[0].runner in planner.VECTOR_RUNNERS
    got, _ = run_program(b.optimized, db, plan=plan)
    ref, _ = run_program(b.optimized, _bm_db(n=40, seed=9), mode="naive")
    assert np.array_equal(np.asarray(got), np.asarray(ref))
    own, _ = run_program(b.optimized, db, mode="naive")
    assert not np.array_equal(np.asarray(got), np.asarray(own))
    # a family that cannot take a vector runner must refuse the override
    m = programs.mlm()
    db_m = m.make_db(datasets.random_recursive_tree(20, seed=1))
    with pytest.raises(ValueError, match="override cannot be honored"):
        planner.plan_program(m.optimized, db_m,
                             edges=db_m.relations["E"])


def test_auto_and_forced_plans_do_not_alias_staged_cache(monkeypatch):
    """Same runner, different storage decisions (auto sparsifies, forced
    keeps) must not share staged closures."""
    b = programs.bc(dmax=8)
    db = b.make_db(datasets.erdos_renyi(40, 1.5, seed=0))
    plan = planner.plan_for(b.original, db)
    # the scenario needs a stratum where only storage differs from the
    # forced plan: dense_naive chosen with E re-homed to sparse
    sig_sp = plan.strata[2]
    assert sig_sp.runner == "dense_naive" and \
        sig_sp.storage == {"E": "sparse"}, (sig_sp.runner, sig_sp.storage)
    calls = {"ico": 0}
    real_ico = prog_mod.make_ico

    def count(*a, **k):
        calls["ico"] += 1
        return real_ico(*a, **k)

    monkeypatch.setattr(prog_mod, "make_ico", count)
    a_auto, _ = run_program(b.original, db, mode="auto")
    auto_calls = calls["ico"]
    a_forced, _ = run_program(b.original, db, mode="naive")
    assert calls["ico"] == auto_calls + len(b.original.strata)
    assert np.array_equal(np.asarray(a_auto), np.asarray(a_forced))


def test_different_databases_do_not_share_staged_plans():
    """Two same-shape databases must produce their own answers even
    through the staged-plan cache."""
    b = programs.bm(a=0)
    prog = b.optimized
    db1 = _bm_db(n=40, seed=1)
    db2 = _bm_db(n=40, seed=9)
    a1, _ = run_program(prog, db1)
    a2, _ = run_program(prog, db2)
    r1, _ = run_program(prog, db1, mode="naive")
    r2, _ = run_program(prog, db2, mode="naive")
    assert np.array_equal(np.asarray(a1), np.asarray(r1))
    assert np.array_equal(np.asarray(a2), np.asarray(r2))
    assert not np.array_equal(np.asarray(r1), np.asarray(r2))


# --------------------------------------------------------------------------
# Construction hoisting (satellite: cache hits skip make_ico/init_state)
# --------------------------------------------------------------------------


def test_cache_hit_skips_ico_and_init_construction(monkeypatch):
    b = programs.bm(a=0)
    prog = b.optimized
    db = _bm_db(n=30, seed=4)
    calls = {"ico": 0, "init": 0}
    real_ico, real_init = prog_mod.make_ico, prog_mod.init_state

    def count_ico(*a, **k):
        calls["ico"] += 1
        return real_ico(*a, **k)

    def count_init(*a, **k):
        calls["init"] += 1
        return real_init(*a, **k)

    monkeypatch.setattr(prog_mod, "make_ico", count_ico)
    monkeypatch.setattr(prog_mod, "init_state", count_init)
    run_program(prog, db, mode="seminaive")
    first = dict(calls)
    assert first["ico"] == 1 and first["init"] == 1
    run_program(prog, db, mode="seminaive")
    assert calls == first  # cache hit: nothing rebuilt


# --------------------------------------------------------------------------
# Scale + serve routing
# --------------------------------------------------------------------------


def test_multi_stratum_second_run_hits_cache(monkeypatch):
    """Later strata key their staged cache on the *input* database, not
    on the previous stratum's fresh output arrays — so a repeat run
    rebuilds nothing."""
    b = programs.bc(dmax=8)
    db = b.make_db(datasets.erdos_renyi(6, 1.5, seed=0))
    calls = {"ico": 0}
    real_ico = prog_mod.make_ico

    def count(*a, **k):
        calls["ico"] += 1
        return real_ico(*a, **k)

    monkeypatch.setattr(prog_mod, "make_ico", count)
    a1, _ = run_program(b.original, db, mode="naive")
    first = calls["ico"]
    assert first == len(b.original.strata)
    a2, _ = run_program(b.original, db, mode="naive")
    assert calls["ico"] == first
    assert np.array_equal(np.asarray(a1), np.asarray(a2))


def test_auto_picks_sparse_path_at_50k():
    """Acceptance: bm at n=50k (sparse adjacency) plans onto the sparse
    vector runners; sssp does too via the edges override."""
    g = datasets.erdos_renyi_sparse(50_000, 8.0, seed=0)
    db = engine.Database(programs.bm(a=0).original.schema, {"id": g.n},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((g.n,), bool)})
    plan = planner.plan_program(programs.bm(a=0).optimized, db)
    assert plan.strata[0].runner in ("sparse_frontier", "sparse_jit")

    b = programs.sssp(a=0, wmax=6, dmax=48)
    gw = datasets.erdos_renyi_sparse(50_000, 6.0, seed=3, weighted=True,
                                     wmax=6)
    db_s = engine.Database(b.original.schema,
                           {"id": gw.n, "w": 6, "d": 48}, {})
    plan_s = planner.plan_program(
        b.optimized, db_s, edges=gw.sparse_adjacency(semiring="trop"))
    assert plan_s.strata[0].runner in ("sparse_frontier", "sparse_jit")


def test_plan_signature_distinguishes_runner_shape_semiring():
    db1 = _bm_db(n=40, seed=1)
    db2 = _bm_db(n=64, seed=1)
    prog = programs.bm(a=0).optimized
    p_auto = planner.plan_program(prog, db1)
    p_forced = planner.plan_program(prog, db1, mode="naive")
    p_other_n = planner.plan_program(prog, db2)
    p_cc = planner.plan_program(programs.cc().optimized,
                                programs.cc().make_db(
                                    datasets.erdos_renyi(40, 14.0, seed=1)))
    sigs = {p.signature for p in (p_auto, p_forced, p_other_n, p_cc)}
    assert len(sigs) == 4
    # re-planning the same cell is deterministic
    assert planner.plan_program(prog, db1).signature == p_auto.signature


def test_serve_families_carry_plans():
    """The serve loop's compile cache keys on (plan.signature, bucket)
    and its runners come from planner.compile_batched."""
    from repro.launch.datalog_serve import DatalogServer
    db = _bm_db(n=60, seed=2, sparse=True)
    server = DatalogServer(max_batch=4)
    fam = server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    assert isinstance(fam.plan, planner.ExecutionPlan)
    assert fam.plan.strata[0].runner == "sparse_jit"
    assert fam.plan.objective == "throughput"
    reqs = [server.submit("reach", s) for s in (0, 5, 9)]
    server.run_until_idle()
    assert {k[0] for k in server._compiled} == {fam.plan.signature}
    for req in reqs:
        ref, _ = run_program(programs.bm(a=req.source).optimized,
                             db.with_storage("E", "dense"),
                             mode="seminaive")
        assert np.array_equal(req.result, np.asarray(ref))


def test_throughput_objective_requires_vector_runner():
    b = programs.mlm()
    db = b.make_db(datasets.random_recursive_tree(20, seed=1))
    with pytest.raises(ValueError, match="lacks"):
        planner.plan_program(b.optimized, db, objective="throughput",
                             require_vector=True)


# --------------------------------------------------------------------------
# HLO cost model
# --------------------------------------------------------------------------


def test_hlo_cost_model_prices_candidates():
    db = _bm_db(n=24, seed=3)
    plan = planner.plan_program(programs.bm(a=0).optimized, db,
                                cost_model="hlo")
    sp = plan.strata[0]
    priced = [c for c in sp.considered.values() if c.source == "hlo"]
    assert priced, sp.considered
    assert all(c.flops_per_iter > 0 for c in priced)
    # the hlo-priced plan still executes correctly
    got, _ = run_program(programs.bm(a=0).optimized, db, plan=plan)
    ref, _ = run_program(programs.bm(a=0).optimized, db, mode="naive")
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_sharded_crossover_pins_pick_to_empirical_winner():
    """Regression for the BENCH_sharded.json mispick: offered an
    8-device mesh, the planner must keep the single-device runner below
    the measured crossover (toy graphs, where BENCH_sharded.json
    records D=8 losing ~0.8×) and take the partition above it — which
    with the Δ-sparse exchange already happens at 100k vertices
    (measured ~1.4×), not just at multi-million-edge packs.  All sides
    use the planning-only nnz/shape metadata — no big buffers
    materialize."""
    import dataclasses

    b = programs.sssp(a=0, wmax=4, dmax=40)
    g = datasets.erdos_renyi(64, 2.5, seed=4, weighted=True, wmax=4)
    seed_rel = g.sparse_adjacency(semiring="trop")

    def plan_at(n, nnz, objective):
        edges = dataclasses.replace(seed_rel, nnz=np.asarray(nnz),
                                    shape=(n, n))
        db = engine.Database(b.original.schema,
                             {"id": n, "w": 4, "d": 40}, {})
        return planner.plan_program(b.optimized, db, edges=edges,
                                    mesh=8, objective=objective)

    # below the crossover: 20k vertices / 80k edges ≈ 12.5k work/device
    # per iteration — the bench's small size measures one device winning
    for objective in ("latency", "throughput"):
        sp = plan_at(20_000, 80_000, objective).strata[0]
        assert sp.runner != "sparse_sharded", objective
        assert "crossover" in sp.rejected["sparse_sharded"]
        assert sp.partition is None

    # above the crossover: both the 100k regime (where the PR-5 dense
    # exchange lost 30–50× but the Δ-sparse exchange wins ~1.4×) and
    # the multi-million-edge packs — the pick follows the measurement
    for n, nnz in ((100_000, 800_000), (2_000_000, 16_000_000)):
        sp = plan_at(n, nnz, "throughput").strata[0]
        assert sp.runner == "sparse_sharded", n
        assert "Δ-exchange" in sp.partition
        assert "sparse_sharded" in sp.considered
