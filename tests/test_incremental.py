"""Incremental fixpoint maintenance (DESIGN.md §5): delta-restart must
agree *exactly* with from-scratch recomputation — across semirings,
single vs batched deltas, the capacity-doubling re-pad path, and the
planner-routed ``refresh_program`` policy layer (which must fall back to
a full recompute, with a reason, whenever warm restart would be
unsound)."""

import numpy as np
import pytest

import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from helpers import given, settings, strategies as st

from repro.core import engine, planner
from repro.core.program import run_program
from repro.datalog import datasets, programs
from repro.incremental import (DeltaLog, delta_restart_fixpoint,
                               refresh_program)
from repro.sparse import SparseRelation, sparse_seminaive_fixpoint
from repro.sparse.fixpoint import csr_index


def _rand_rel(rng, n, avg_deg, semiring, *, capacity=None):
    g = datasets.erdos_renyi(n, avg_deg, seed=int(rng.integers(1 << 30)),
                             weighted=semiring != "bool", wmax=6)
    return g.sparse_adjacency(semiring=semiring, capacity=capacity)


def _rand_delta(rng, n, k, semiring):
    coords = np.stack([rng.integers(0, n, k), rng.integers(0, n, k)], 1)
    values = (np.ones(k, bool) if semiring == "bool"
              else rng.integers(1, 6, k).astype(np.float32))
    return coords, values


def _trop_init(n, s):
    init = np.full(n, np.inf, np.float32)
    init[s] = 0.0
    return init


def _bool_init(n, s):
    init = np.zeros(n, bool)
    init[s] = True
    return init


# --------------------------------------------------------------------------
# Randomized differential: delta-restart ≡ from-scratch
# --------------------------------------------------------------------------


@pytest.mark.parametrize("semiring", ["bool", "trop"])
@pytest.mark.parametrize("mode", ["frontier", "jit"])
@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_delta_restart_matches_scratch(semiring, mode, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
    n = data.draw(st.integers(10, 40))
    k = data.draw(st.integers(1, 12))
    rel = _rand_rel(rng, n, 2.0, semiring)
    init = (_bool_init if semiring == "bool" else _trop_init)(
        n, int(rng.integers(0, n)))
    y0, _ = sparse_seminaive_fixpoint(rel, init, mode=mode)

    coords, values = _rand_delta(rng, n, k, semiring)
    delta = SparseRelation.from_coo(coords, values, rel.shape, semiring,
                                    lib="np")
    rel2 = rel.apply_delta(coords, values)
    y_warm, _ = delta_restart_fixpoint(rel2, delta, np.asarray(y0),
                                       mode=mode)
    y_cold, _ = sparse_seminaive_fixpoint(rel2, init, mode=mode)
    assert np.array_equal(np.asarray(y_warm), np.asarray(y_cold))


@pytest.mark.parametrize("semiring", ["bool", "trop"])
@settings(max_examples=6, deadline=None)
@given(data=st.data())
def test_batched_delta_repair_matches_scratch(semiring, data):
    """(B, n) warm state repaired in one SpMM pass ≡ B from-scratch
    solves on the mutated graph."""
    rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
    n = data.draw(st.integers(12, 32))
    b = data.draw(st.integers(2, 5))
    rel = _rand_rel(rng, n, 2.0, semiring)
    mk = _bool_init if semiring == "bool" else _trop_init
    inits = np.stack([mk(n, int(rng.integers(0, n))) for _ in range(b)])
    y0, _ = sparse_seminaive_fixpoint(rel, inits, mode="jit")

    coords, values = _rand_delta(rng, n, 4, semiring)
    delta = SparseRelation.from_coo(coords, values, rel.shape, semiring,
                                    lib="np")
    rel2 = rel.apply_delta(coords, values)
    y_warm, _ = delta_restart_fixpoint(rel2, delta, np.asarray(y0),
                                       mode="jit")
    y_cold, _ = sparse_seminaive_fixpoint(rel2, inits, mode="jit")
    assert np.array_equal(np.asarray(y_warm), np.asarray(y_cold))


@settings(max_examples=6, deadline=None)
@given(data=st.data())
def test_capacity_doubling_repad_path(data):
    """Deltas bigger than the padded slack re-pad at the doubled
    capacity — same answers, prefix-preserving layout, and the CSR
    overlay stays consistent with a cold rebuild."""
    rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
    n = data.draw(st.integers(10, 24))
    rel = _rand_rel(rng, n, 1.5, "trop")        # capacity == nnz: 0 slack
    cap0 = rel.capacity
    k = cap0 + data.draw(st.integers(1, 8))     # guaranteed overflow
    coords, values = _rand_delta(rng, n, k, "trop")
    rel2 = rel.apply_delta(coords, values)
    assert rel2.capacity > cap0
    assert rel2.capacity >= int(np.asarray(rel2.nnz))

    # dense semantics: ⊕-merge of the delta into the old relation
    sr_zero = np.float32(np.inf)
    want = np.asarray(rel.to_dense()).copy()
    np.minimum.at(want, tuple(coords.T), values)
    assert np.array_equal(np.asarray(rel2.to_dense()),
                          np.where(want == sr_zero, sr_zero, want))

    init = _trop_init(n, int(rng.integers(0, n)))
    y0, _ = sparse_seminaive_fixpoint(rel, init, mode="frontier")
    delta = SparseRelation.from_coo(coords, values, rel.shape, "trop",
                                    lib="np")
    y_warm, _ = delta_restart_fixpoint(rel2, delta, np.asarray(y0),
                                       mode="frontier")
    y_cold, _ = sparse_seminaive_fixpoint(rel2, init, mode="frontier")
    assert np.array_equal(np.asarray(y_warm), np.asarray(y_cold))


def test_csr_overlay_chain_and_compaction():
    """A chain of apply_delta calls keeps the frontier runner exact, both
    below the overlay-compaction threshold (index extended in O(nnz(Δ)))
    and above it (child deliberately left unregistered → rebuilt)."""
    rng = np.random.default_rng(7)
    n = 30
    rel = _rand_rel(rng, n, 2.0, "bool")
    csr_index(rel)                       # warm the cached base index
    init = _bool_init(n, 3)
    cur = rel
    for step in range(3):                # small deltas: overlay extension
        coords, values = _rand_delta(rng, n, 5, "bool")
        cur = cur.apply_delta(coords, values)
        y, _ = sparse_seminaive_fixpoint(cur, init, mode="frontier")
        cold = SparseRelation.from_dense(np.asarray(cur.to_dense()),
                                         "bool")
        y_cold, _ = sparse_seminaive_fixpoint(cold, init, mode="frontier")
        assert np.array_equal(np.asarray(y), np.asarray(y_cold)), step

    # past the compaction threshold (>1024 overlay rows on a tiny base)
    coords, values = _rand_delta(rng, n, 1500, "bool")
    big = cur.apply_delta(coords, values)
    y, _ = sparse_seminaive_fixpoint(big, init, mode="frontier")
    cold = SparseRelation.from_dense(np.asarray(big.to_dense()), "bool")
    y_cold, _ = sparse_seminaive_fixpoint(cold, init, mode="frontier")
    assert np.array_equal(np.asarray(y), np.asarray(y_cold))


# --------------------------------------------------------------------------
# apply_delta semantics
# --------------------------------------------------------------------------


def test_trop_weight_decrease_and_absorbed_increase():
    rel = SparseRelation.from_coo([[0, 1]], [4.0], (3, 3), "trop",
                                  capacity=4)
    dec = rel.apply_delta([[0, 1]], [2.0])    # decrease: min absorbs old
    assert np.asarray(dec.to_dense())[0, 1] == 2.0
    inc = rel.apply_delta([[0, 1]], [9.0])    # increase: ⊕-merge no-op
    assert np.asarray(inc.to_dense())[0, 1] == 4.0


def test_apply_delta_validates_and_drops_zeros():
    rel = SparseRelation.from_coo([[0, 1]], [True], (3, 3), "bool",
                                  capacity=4)
    with pytest.raises(ValueError, match="out of range"):
        rel.apply_delta([[0, 3]])
    same = rel.apply_delta([[1, 2]], [False])  # explicit 0̄: identity
    assert int(np.asarray(same.nnz)) == 1


def test_database_apply_delta_dense_and_sparse():
    schema = programs.bm(a=0).original.schema
    g = datasets.erdos_renyi(12, 1.5, seed=0)
    dbs = engine.Database(schema, {"id": 12},
                          {"E": g.sparse_adjacency(),
                           "V": jnp.ones((12,), bool)})
    dbd = dbs.with_storage("E", "dense")
    log = DeltaLog().insert("E", [[2, 7], [7, 11]])
    for db in (dbs, dbd):
        out = db.apply_delta(log)
        dense = np.asarray(out.relations["E"] if db is dbd
                           else out.relations["E"].to_dense())
        assert dense[2, 7] and dense[7, 11]
    gone = dbs.apply_delta(DeltaLog().insert("E", [[2, 7]])) \
        .apply_delta(DeltaLog().delete("E", [[2, 7]]))
    assert not np.asarray(gone.relations["E"].to_dense())[2, 7]


# --------------------------------------------------------------------------
# refresh_program: the planner-routed policy layer
# --------------------------------------------------------------------------


def _bm_setup(n=40, seed=2):
    g = datasets.erdos_renyi(n, 1.5, seed=seed)
    db = engine.Database(programs.bm(a=0).original.schema, {"id": n},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((n,), bool)})
    return programs.bm(a=0).optimized, db


@settings(max_examples=6, deadline=None)
@given(data=st.data())
def test_refresh_program_differential_bool(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
    prog, db = _bm_setup(seed=int(rng.integers(1 << 20)))
    prev, _ = run_program(prog, db)
    coords, _ = _rand_delta(rng, 40, int(rng.integers(1, 6)), "bool")
    log = DeltaLog().insert("E", coords)
    y, db2, rep = refresh_program(prog, db, np.asarray(prev), log)
    scratch, _ = run_program(prog, db2)
    assert np.array_equal(np.asarray(y), np.asarray(scratch))
    assert rep.strategy == "delta_restart"


def test_refresh_program_nat_falls_back_full_and_exact():
    """ℕ (counting) has no ⊖ — delta-restart is infeasible; refresh must
    fall back to a full recompute and still be exact."""
    b = programs.mlm()
    g = datasets.random_recursive_tree(14, seed=3)
    db = b.make_db(g)
    db = db.with_relations(
        {"E": SparseRelation.from_dense(np.asarray(db.relations["E"]),
                                        "bool", capacity=64)})
    prev, _ = run_program(b.optimized, db)
    log = DeltaLog().insert("E", [[0, 9]])
    y, db2, rep = refresh_program(b.optimized, db, np.asarray(prev), log)
    scratch, _ = run_program(b.optimized, db2)
    assert np.array_equal(np.asarray(y), np.asarray(scratch))
    assert rep.strategy == "full"


def _edge_init_prog(a=0):
    """Q(y) := E(a, y) ⊕ ⊕_z Q(z) ⊗ E(z, y) — the init term reads the
    edge relation itself, so a ⊕-merge into E changes *both* the linear
    operator and the init vector."""
    from repro.core import ir
    from repro.core.program import Program, Rule, Stratum

    schema = programs.bm(a=0).original.schema
    body = ir.SSP(("y",), (
        ir.Term((ir.RelAtom("E", (ir.C(a), "y")),), ()),
        ir.Term((ir.RelAtom("Q", ("z",)), ir.RelAtom("E", ("z", "y"))),
                ("z",))), "bool")
    return Program("edge_init", schema,
                   [Stratum({"Q": Rule("Q", body)})],
                   [Rule("Qans", ir.SSP(("y",), (ir.Term(
                       (ir.RelAtom("Q", ("y",)),), ()),), "bool"))])


def test_refresh_edge_fed_init_falls_back_full():
    """A merge into an edge relation that also feeds the init term must
    NOT delta-restart: the Δ-seed (y* ⊗ ΔE) ⊖ y* misses the init
    contribution entirely (here y* is all-0̄, so the seed derives
    nothing while the true answer becomes non-empty)."""
    n = 4
    db = engine.Database(programs.bm(a=0).original.schema, {"id": n},
                         {"E": SparseRelation.from_coo(
                             [[1, 2]], [True], (n, n), "bool",
                             capacity=8),
                          "V": jnp.ones((n,), bool)})
    prog = _edge_init_prog(a=0)
    prev, _ = run_program(prog, db)
    assert not np.asarray(prev).any()
    log = DeltaLog().insert("E", [[0, 1]])
    y, db2, rep = refresh_program(prog, db, np.asarray(prev), log)
    scratch, _ = run_program(prog, db2)
    assert np.array_equal(np.asarray(y), np.asarray(scratch))
    assert np.asarray(y).any()
    assert rep.strategy == "full" and "feeds the init term" in rep.reason


def test_refresh_fallback_reasons():
    prog, db = _bm_setup()
    prev, _ = run_program(prog, db)
    prev = np.asarray(prev)

    # a delete no longer means full recompute: the synthesized
    # maintenance rule (DESIGN.md §11) repairs it — but with a zero
    # synthesis budget (and a cold rule cache) it falls back with the
    # recorded failure
    from repro.incremental.maintenance import clear_rule_cache
    clear_rule_cache()
    _, _, rep = refresh_program(prog, db, prev,
                                DeltaLog().delete("E", [[0, 1]]),
                                synth_budget_s=0.0)
    assert rep.strategy == "full" and "synthesis" in rep.reason

    clear_rule_cache()
    _, _, rep = refresh_program(prog, db, prev,
                                DeltaLog().delete("E", [[0, 1]]))
    assert rep.strategy == "synth_maintenance"
    assert "⊖-recount" in rep.reason

    _, _, rep = refresh_program(prog, db, None,
                                DeltaLog().insert("E", [[0, 1]]))
    assert rep.strategy == "full" and "no previous solution" in rep.reason

    log = DeltaLog().insert("E", [[0, 1]]).insert("V", [[2]])
    _, _, rep = refresh_program(prog, db, prev, log)
    assert rep.strategy == "full" and "outside the linear" in rep.reason


# --------------------------------------------------------------------------
# Planner: the objective="incremental" candidate
# --------------------------------------------------------------------------


def test_planner_incremental_candidate():
    prog, db = _bm_setup(n=200, seed=5)
    plan = planner.plan_program(prog, db, objective="incremental",
                                delta_nnz=2)
    sp = plan.strata[0]
    assert sp.runner == "delta_restart"
    assert "delta_restart" in sp.considered
    assert sp.considered["delta_restart"].total < min(
        v.total for k, v in sp.considered.items() if k != "delta_restart")
    assert "warm restart" in planner.explain(plan)


def test_planner_incremental_requires_delta():
    prog, db = _bm_setup()
    plan = planner.plan_program(prog, db, objective="incremental")
    sp = plan.strata[0]
    assert sp.runner != "delta_restart"
    assert "no update delta" in sp.rejected["delta_restart"]


def test_planner_latency_never_offers_delta_restart():
    prog, db = _bm_setup()
    plan = planner.plan_program(prog, db, delta_nnz=3)  # objective=latency
    sp = plan.strata[0]
    assert "delta_restart" not in sp.considered
    assert "delta_restart" not in sp.rejected


def test_delta_restart_cannot_be_forced_or_executed_cold():
    prog, db = _bm_setup()
    with pytest.raises(ValueError, match="cannot be forced"):
        planner.plan_program(prog, db, mode="delta_restart")
    plan = planner.plan_program(prog, db, objective="incremental",
                                delta_nnz=1)
    with pytest.raises(ValueError, match="refresh_program"):
        planner.execute_plan(plan, prog, db)
