"""Shared test utilities: brute-force SSP oracle, random query generators,
and a minimal fallback for ``hypothesis`` (not installed everywhere).

The shim implements just the strategy surface our property tests use
(``sampled_from``/``integers``/``one_of``/``builds``/``lists``/``data``)
with deterministic seeded draws, so the tier-1 suite collects and runs
without the real library.  Test modules import it as::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from helpers import given, settings, strategies as st
"""

from __future__ import annotations

import functools
import inspect
import itertools
import zlib

import numpy as np

from repro.core import engine, ir
from repro.core import semiring as sr_mod


def brute_force_eval(e: ir.SSP, db: engine.Database, hints=None) -> np.ndarray:
    """Evaluate an SSP by enumerating every variable assignment.

    The independent oracle for the contraction planner: O(n^vars), only for
    tiny domains.
    """
    sr = sr_mod.get(e.semiring, lib="np")
    sorts = engine.infer_var_sorts(e, db.schema, hints)
    out_shape = tuple(db.domains[sorts[h]] for h in e.head)
    acc = np.full(out_shape, sr.zero, sr.dtype)

    for t in e.terms:
        vars_ = sorted(t.vars() | set(e.head))
        doms = [range(db.domains[sorts[v]]) for v in vars_]
        for assign in itertools.product(*doms):
            env = dict(zip(vars_, assign))
            val = np.asarray(sr.one, sr.dtype)
            for a in t.atoms:
                val = sr.mul(val, _atom_value(a, env, db, sr))
            idx = tuple(env[h] for h in e.head)
            acc[idx] = sr.add(acc[idx], val)
    return acc


def _atom_value(a, env, db, sr):
    def argv(x):
        return x.value if isinstance(x, ir.C) else env[x]

    if isinstance(a, ir.RelAtom):
        v = np.asarray(db.relations[a.name])[tuple(argv(x) for x in a.args)]
        src = sr_mod.get(db.schema[a.name].semiring, lib="np")
        if a.neg:
            v = not bool(v)
        if src.name == "bool" and sr.name != "bool":
            return sr.from_bool(np.asarray(v))
        if src.name != sr.name and src.name != "bool":
            return np.asarray(sr.zero if v == src.zero else v, sr.dtype)
        return np.asarray(v)
    if isinstance(a, ir.PredAtom):
        vals = [argv(x) for x in a.args]
        table = {"eq": lambda x, y: x == y, "neq": lambda x, y: x != y,
                 "lt": lambda x, y: x < y, "le": lambda x, y: x <= y,
                 "sum3": lambda x, y, z: x == y + z,
                 "succ": lambda x, y: x == y + 1,
                 "winlt": lambda x, y: 1 <= x < y}
        return sr.from_bool(np.asarray(table[a.pred](*vals)))
    if isinstance(a, ir.ValAtom):
        return np.asarray(float(env[a.var]), sr.dtype)
    if isinstance(a, ir.ValFnAtom):
        vals = [float(argv(x)) for x in a.args]
        if a.fn == "mulratio":
            return np.asarray(vals[0] * vals[1] / max(vals[2], 1.0), sr.dtype)
        return np.asarray(vals[0] + 1.0, sr.dtype)
    return np.asarray(a.value, sr.dtype)


def values_close(a, b, atol=1e-4):
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype == bool:
        return bool((a == b).all())
    return bool(np.allclose(a, b, atol=atol, rtol=1e-4, equal_nan=True))


# --------------------------------------------------------------------------
# Minimal hypothesis fallback (see module docstring)
# --------------------------------------------------------------------------


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class _DataObject:
    """Stand-in for ``st.data()``'s draw handle."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy: _Strategy):
        return strategy.draw(self._rng)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(lambda rng: _DataObject(rng))


class _Strategies:
    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def one_of(*strategies):
        strategies = list(strategies)
        return _Strategy(lambda rng: strategies[
            int(rng.integers(0, len(strategies)))].draw(rng))

    @staticmethod
    def builds(fn, *strategies):
        return _Strategy(lambda rng: fn(*(s.draw(rng) for s in strategies)))

    @staticmethod
    def lists(strategy, min_size=0, max_size=10):
        def draw(rng):
            k = int(rng.integers(min_size, max_size + 1))
            return [strategy.draw(rng) for _ in range(k)]
        return _Strategy(draw)

    @staticmethod
    def data():
        return _DataStrategy()


strategies = _Strategies()


def settings(max_examples: int = 20, **_ignored):
    """Attach the example budget to the (already ``given``-wrapped) test."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strategy_kwargs):
    """Run the test over ``max_examples`` deterministic random draws."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", 20)
            base = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = np.random.default_rng((base + i) % 2**31)
                drawn = {k: s.draw(rng)
                         for k, s in strategy_kwargs.items()}
                fn(*args, **kwargs, **drawn)

        # hide strategy-bound params from pytest's fixture resolution
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items()
                  if name not in strategy_kwargs]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper
    return deco
