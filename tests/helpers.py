"""Shared test utilities: brute-force SSP oracle + random query generators."""

from __future__ import annotations

import itertools

import numpy as np

from repro.core import engine, ir
from repro.core import semiring as sr_mod


def brute_force_eval(e: ir.SSP, db: engine.Database, hints=None) -> np.ndarray:
    """Evaluate an SSP by enumerating every variable assignment.

    The independent oracle for the contraction planner: O(n^vars), only for
    tiny domains.
    """
    sr = sr_mod.get(e.semiring, lib="np")
    sorts = engine.infer_var_sorts(e, db.schema, hints)
    out_shape = tuple(db.domains[sorts[h]] for h in e.head)
    acc = np.full(out_shape, sr.zero, sr.dtype)

    for t in e.terms:
        vars_ = sorted(t.vars() | set(e.head))
        doms = [range(db.domains[sorts[v]]) for v in vars_]
        for assign in itertools.product(*doms):
            env = dict(zip(vars_, assign))
            val = np.asarray(sr.one, sr.dtype)
            for a in t.atoms:
                val = sr.mul(val, _atom_value(a, env, db, sr))
            idx = tuple(env[h] for h in e.head)
            acc[idx] = sr.add(acc[idx], val)
    return acc


def _atom_value(a, env, db, sr):
    def argv(x):
        return x.value if isinstance(x, ir.C) else env[x]

    if isinstance(a, ir.RelAtom):
        v = np.asarray(db.relations[a.name])[tuple(argv(x) for x in a.args)]
        src = sr_mod.get(db.schema[a.name].semiring, lib="np")
        if a.neg:
            v = not bool(v)
        if src.name == "bool" and sr.name != "bool":
            return sr.from_bool(np.asarray(v))
        if src.name != sr.name and src.name != "bool":
            return np.asarray(sr.zero if v == src.zero else v, sr.dtype)
        return np.asarray(v)
    if isinstance(a, ir.PredAtom):
        vals = [argv(x) for x in a.args]
        table = {"eq": lambda x, y: x == y, "neq": lambda x, y: x != y,
                 "lt": lambda x, y: x < y, "le": lambda x, y: x <= y,
                 "sum3": lambda x, y, z: x == y + z,
                 "succ": lambda x, y: x == y + 1,
                 "winlt": lambda x, y: 1 <= x < y}
        return sr.from_bool(np.asarray(table[a.pred](*vals)))
    if isinstance(a, ir.ValAtom):
        return np.asarray(float(env[a.var]), sr.dtype)
    if isinstance(a, ir.ValFnAtom):
        vals = [float(argv(x)) for x in a.args]
        if a.fn == "mulratio":
            return np.asarray(vals[0] * vals[1] / max(vals[2], 1.0), sr.dtype)
        return np.asarray(vals[0] + 1.0, sr.dtype)
    return np.asarray(a.value, sr.dtype)


def values_close(a, b, atol=1e-4):
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype == bool:
        return bool((a == b).all())
    return bool(np.allclose(a, b, atol=atol, rtol=1e-4, equal_nan=True))
