"""Graph-axis sharded fixpoints (DESIGN.md §6): shard/unshard round-trip
properties across semirings and ragged nnz, delta routing to owning
shards, planner device-dimension goldens, forced ≡ auto parity at
D ∈ {1, 2, 8}, and sharded-vs-single-device fixpoint exactness.

Device-bound tests skip when the host exposes fewer devices than the
mesh needs; CI's ``test-distributed`` job (``make test-dist``) runs the
whole file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from helpers import given, settings, strategies as st

from repro.core import engine, planner
from repro.core.program import run_program
from repro.datalog import datasets, programs
from repro.distributed import datalog as dd
from repro.incremental import delta_seed
from repro.launch.mesh import make_graph_mesh
from repro.sparse import contract
from repro.sparse.coo import SparseRelation
from repro.sparse.fixpoint import (resume_fixpoint,
                                   sparse_seminaive_fixpoint)

NDEV = len(jax.devices())
CPU = jax.default_backend() == "cpu"

SEMIRINGS = ("bool", "trop", "maxplus", "nat")


def needs_devices(d):
    return pytest.mark.skipif(
        NDEV < d, reason=f"needs {d} devices (have {NDEV}; run via "
                         f"make test-dist)")


def _random_rel(rng, n: int, semiring: str, nnz: int,
                capacity: int | None = None) -> SparseRelation:
    coords = np.stack([rng.integers(0, n, nnz), rng.integers(0, n, nnz)],
                      axis=1)
    if semiring == "bool":
        values = np.ones(nnz, bool)
    else:
        values = rng.integers(1, 6, nnz).astype(np.float32)
    return SparseRelation.from_coo(coords, values, (n, n), semiring,
                                   capacity=capacity, lib="np")


def _dense(rel) -> np.ndarray:
    return np.asarray(rel.to_dense())


# --------------------------------------------------------------------------
# shard/unshard round-trip (host-side: no devices needed)
# --------------------------------------------------------------------------


@settings(max_examples=30)
@given(data=st.data())
def test_shard_roundtrip_property(data):
    """unshard(shard_relation(rel, D)) == rel across semirings, sizes,
    ragged nnz, and D values that do not divide n."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    semiring = data.draw(st.sampled_from(SEMIRINGS))
    n = data.draw(st.integers(1, 40))
    nnz = data.draw(st.integers(0, 80))
    d = data.draw(st.integers(1, 9))
    rel = _random_rel(rng, n, semiring, nnz)
    sh = dd.shard_relation(rel, d)
    assert sh.d == d
    assert sh.row_block * d >= n
    # every shard's live tuples carry block-local destinations and
    # sources that invert to real vertices under the balance relabeling
    host = sh.as_np()
    for s in range(d):
        k = int(host.nnz[s])
        assert (host.coords[s, :k, 1] < sh.row_block).all()
        src = host.coords[s, :k, 0]
        assert (src < sh.n_pad).all()
        if host.inv is not None:
            src = host.inv[src]
        assert (src < n).all()
    # live counts partition the coalesced nnz exactly
    assert int(np.asarray(host.nnz).sum()) == int(np.asarray(
        rel.as_np().nnz))
    assert np.array_equal(_dense(dd.unshard(sh)), _dense(rel))


def test_shard_ragged_capacity_is_worst_shard():
    """All edges landing on one destination vertex: with ``balance=False``
    one hot shard sets the uniform capacity and the rest stay
    all-padding; the default balance relabeling cannot split a single
    hot *vertex* either, but must still round-trip exactly."""
    n, d = 24, 4
    coords = np.stack([np.arange(12) % n, np.full(12, 1)], axis=1)
    rel = SparseRelation.from_coo(coords, np.ones(12, bool), (n, n),
                                  "bool", lib="np")
    sh = dd.shard_relation(rel, d, balance=False)
    nnz = np.asarray(sh.as_np().nnz)
    assert nnz.tolist() == [12, 0, 0, 0]
    assert sh.capacity == 12
    assert sh.perm is None
    assert np.array_equal(_dense(dd.unshard(sh)), _dense(rel))
    bal = dd.shard_relation(rel, d)
    assert bal.capacity == 12  # one vertex owns every edge: no split
    assert np.array_equal(_dense(dd.unshard(bal)), _dense(rel))


def test_balance_permutation_evens_edge_counts():
    """The snake-deal relabeling bounds the worst shard near the mean on
    a skewed graph, while a contiguous split concentrates the hubs."""
    rng = np.random.default_rng(0)
    n, d = 1024, 8
    # hub-heavy destinations: low vertex ids get most edges
    dst = (rng.pareto(1.0, 6000) * 8).astype(np.int64) % n
    src = rng.integers(0, n, 6000)
    rel = SparseRelation.from_coo(np.stack([src, dst], axis=1),
                                  np.ones(6000, bool), (n, n), "bool",
                                  lib="np")
    plain = dd.shard_relation(rel, d, balance=False)
    bal = dd.shard_relation(rel, d)
    total = bal.total_nnz()
    assert bal.total_nnz() == plain.total_nnz()
    mean = total / d
    assert bal.capacity <= 1.25 * mean
    assert bal.capacity < plain.capacity
    assert np.array_equal(_dense(dd.unshard(bal)), _dense(rel))


def test_shard_requires_binary():
    rel = SparseRelation.from_coo(np.zeros((1, 3), np.int64), [1.0],
                                  (4, 4, 4), "trop", lib="np")
    with pytest.raises(ValueError, match="binary"):
        dd.shard_relation(rel, 2)
    with pytest.raises((ValueError, TypeError)):
        dd.mesh_size("nope")


# --------------------------------------------------------------------------
# apply_delta: routing to owning shards, capacity discipline
# --------------------------------------------------------------------------


@settings(max_examples=20)
@given(data=st.data())
def test_apply_delta_matches_unsharded(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    semiring = data.draw(st.sampled_from(("bool", "trop", "nat")))
    n = data.draw(st.integers(2, 30))
    d = data.draw(st.integers(1, 5))
    rel = _random_rel(rng, n, semiring, data.draw(st.integers(1, 40)),
                      capacity=128)
    sh = dd.shard_relation(rel, d)
    k = data.draw(st.integers(1, 20))
    coords = np.stack([rng.integers(0, n, k), rng.integers(0, n, k)],
                      axis=1)
    values = None if semiring == "bool" else \
        rng.integers(1, 6, k).astype(np.float32)
    got = dd.unshard(sh.apply_delta(coords, values))
    want = rel.apply_delta(coords, values)
    assert np.array_equal(_dense(got), _dense(want))


def test_apply_delta_keeps_capacity_within_padding():
    """Deltas that fit the per-shard padding leave the static capacity —
    and therefore any compiled consumer's trace — unchanged; overflow
    re-pads every shard to one power-of-two capacity."""
    rng = np.random.default_rng(0)
    n = 24
    coords = np.stack([np.arange(12) % n, np.full(12, 1)], axis=1)
    rel = SparseRelation.from_coo(coords, np.ones(12, np.float32),
                                  (n, n), "trop", lib="np")
    sh = dd.shard_relation(rel, 4)   # shard 0 full, shards 1–3 padding
    cap = sh.capacity
    small = sh.apply_delta([[0, 13]], [2.0])  # routes into shard 2's pad
    assert small.capacity == cap
    big = small.apply_delta(
        np.stack([rng.integers(0, n, 4 * cap),
                  np.ones(4 * cap, np.int64)], axis=1),
        np.ones(4 * cap, np.float32))
    assert big.capacity > cap
    # doubling re-pad: the new capacity is the old one shifted left
    assert big.capacity % cap == 0
    assert (big.capacity // cap) & (big.capacity // cap - 1) == 0
    # routing equivalence across the re-pad is covered by the property
    # test above; here the capacity discipline alone is under test


def test_apply_delta_rejects_out_of_range():
    sh = dd.shard_relation(_random_rel(np.random.default_rng(0), 8,
                                       "bool", 4), 2)
    with pytest.raises(ValueError, match="out of range"):
        sh.apply_delta([[0, 9]])


# --------------------------------------------------------------------------
# planner: the device dimension
# --------------------------------------------------------------------------


def _sssp_plan(mesh, n=60, seed=4):
    b = programs.sssp(a=0, wmax=4, dmax=40)
    g = datasets.erdos_renyi(n, 2.5, seed=seed, weighted=True, wmax=4)
    rel = g.sparse_adjacency(semiring="trop")
    db = engine.Database(b.original.schema, {"id": n, "w": 4, "d": 40},
                         {})
    return planner.plan_program(b.optimized, db, edges=rel, mesh=mesh), b


@pytest.mark.skipif(not CPU, reason="golden plans assume the CPU backend")
def test_explain_golden_sharded_sssp():
    """Full golden for a mesh-priced SSSP plan below the sharding
    crossover: the mesh is offered, the crossover rejection is shown,
    and the single-device frontier runner keeps the regime it wins
    (the old model's 30–50× mispick, BENCH_sharded.json)."""
    import re
    plan, _ = _sssp_plan(mesh=8)
    text = re.sub(r"signature=[0-9a-f]{16}", "signature=<sig>",
                  planner.explain(plan))
    assert text == """\
plan SSSP_opt  mode=auto  objective=latency  signature=<sig>
  stratum 0  runner=sparse_frontier  idbs=SP
    reason      min est. total flops among 2 feasible candidates (cpu host ⇒ frontier worklist)
    cost        90.4 flops/iter × 5 iters  [analytic]
    considered  sparse_frontier=452  sparse_jit=1.06e+03
    rejected    dense_gsn: edges override requires a vector runner (the engine paths read the stored relations, not the override)
    rejected    dense_naive: edges override requires a vector runner (the engine paths read the stored relations, not the override)
    rejected    sparse_frontier_pallas: fused-kernel SpMM is a batched-serving backend (objective='throughput') — single-shot latency keeps the worklist/staged runners
    rejected    sparse_sharded: below the sharding crossover: ≈26.5 work/device/iter < 20000 measured minimum (BENCH_sharded.json) — one device wins
    rejected    vector_dense: linear operator is sparse — the SpMV/SpMM runners cover it
  outputs    SPans"""


@pytest.mark.skipif(not CPU, reason="golden plans assume the CPU backend")
def test_explain_partition_line_above_crossover(monkeypatch):
    """Same program with the crossover floor patched away: the partition
    line reports the Δ-exchange byte pricing next to the dense
    all-gather it displaces."""
    monkeypatch.setattr(planner.SHARDED_COST, "min_work_per_device", 0.0)
    monkeypatch.setattr(planner.SHARDED_COST, "sync_flops_per_device", 0.0)
    plan, _ = _sssp_plan(mesh=8)
    sp = plan.strata[0]
    assert sp.runner == "sparse_sharded"
    assert sp.partition == ("graph axis D=8 × 8 dst rows/shard; "
                            "nnz(E)=152 (≈19/shard); "
                            "Δ-exchange ≈672 B/iter "
                            "(dense all-gather 1680 B)")


def test_planner_rejects_single_device_mesh():
    plan, _ = _sssp_plan(mesh=1)
    sp = plan.strata[0]
    assert sp.runner != "sparse_sharded"
    assert "single device" in sp.rejected["sparse_sharded"]


def test_planner_no_mesh_keeps_plans_unchanged():
    plan, _ = _sssp_plan(mesh=None)
    sp = plan.strata[0]
    assert "sparse_sharded" not in sp.considered
    assert "sparse_sharded" not in sp.rejected
    assert sp.partition is None


def test_planner_dense_operator_rejects_sharded():
    b = programs.cc()
    g = datasets.erdos_renyi(40, 14.0, seed=1)
    plan = planner.plan_program(b.optimized, b.make_db(g), mesh=8)
    sp = plan.strata[0]
    assert "sparse_sharded" in sp.rejected
    assert "dense" in sp.rejected["sparse_sharded"]


def test_forced_sharded_requires_mesh():
    b = programs.bm(a=0)
    g = datasets.erdos_renyi(30, 3.0, seed=0)
    db = engine.Database(b.original.schema, {"id": 30},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((30,), bool)})
    with pytest.raises(ValueError, match="mesh"):
        planner.plan_program(b.optimized, db, mode="sparse_sharded")


@pytest.mark.parametrize("d", [1, 2, 8])
def test_forced_matches_auto(d):
    """Forcing mode="sparse_sharded" on a D-device graph mesh returns
    the same answer as the mesh-free auto plan, for D ∈ {1, 2, 8}."""
    if NDEV < d:
        pytest.skip(f"needs {d} devices (have {NDEV}; run via "
                    f"make test-dist)")
    mesh = make_graph_mesh(d)
    b = programs.bm(a=3)
    g = datasets.powerlaw(120, 3, seed=5)
    db = engine.Database(b.original.schema, {"id": g.n},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((g.n,), bool)})
    auto, _ = run_program(b.optimized, db)
    forced_plan = planner.plan_program(b.optimized, db,
                                       mode="sparse_sharded", mesh=mesh)
    assert forced_plan.strata[0].runner == "sparse_sharded"
    assert "forced" in planner.explain(forced_plan)
    out, stats = planner.execute_plan(forced_plan, b.optimized, db)
    assert np.array_equal(np.asarray(out), np.asarray(auto))


# --------------------------------------------------------------------------
# fixpoint exactness vs the single-device runners
# --------------------------------------------------------------------------


def _init_for(semiring, n, source=0):
    sr_zero = {"bool": False, "trop": np.inf, "maxplus": -np.inf}
    init = np.full(n, sr_zero[semiring],
                   bool if semiring == "bool" else np.float32)
    init[source] = True if semiring == "bool" else 0.0
    return init


def _graph_rel(semiring, n=90, seed=7):
    rng = np.random.default_rng(seed)
    if semiring == "maxplus":
        # longest path needs a DAG to converge: only edges i → j, i < j
        src = rng.integers(0, n - 1, 3 * n)
        off = rng.integers(1, 5, 3 * n)
        dst = np.minimum(src + off, n - 1)
        coords = np.stack([src, dst], axis=1)
        vals = rng.integers(1, 4, 3 * n).astype(np.float32)
        return SparseRelation.from_coo(coords, vals, (n, n), "maxplus",
                                       lib="np")
    g = datasets.powerlaw(n, 3, seed=seed)
    g.weights = rng.integers(1, 6, len(g.edges))
    return g.sparse_adjacency(semiring=semiring)


@needs_devices(2)
@pytest.mark.parametrize("semiring", ["bool", "trop", "maxplus"])
def test_sharded_fixpoint_matches_single_device(semiring):
    rel = _graph_rel(semiring)
    n = rel.shape[0]
    init = _init_for(semiring, n)
    mesh = make_graph_mesh(min(NDEV, 8))
    y0, it0 = sparse_seminaive_fixpoint(rel, init, mode="jit")
    y1, it1 = dd.sharded_seminaive_fixpoint(rel, init, mesh=mesh)
    assert np.array_equal(np.asarray(y0), np.asarray(y1))
    assert int(it0) == int(it1)


@needs_devices(2)
@pytest.mark.parametrize("semiring", ["bool", "trop"])
def test_sharded_batched_matches_single_device(semiring):
    rel = _graph_rel(semiring)
    n = rel.shape[0]
    init = np.stack([_init_for(semiring, n, s) for s in (0, 3, 7, 11)])
    mesh = make_graph_mesh(min(NDEV, 8))
    y0, it0 = sparse_seminaive_fixpoint(rel, init, mode="jit")
    y1, it1 = dd.sharded_seminaive_fixpoint(rel, init, mesh=mesh)
    assert np.array_equal(np.asarray(y0), np.asarray(y1))
    assert np.array_equal(np.asarray(it0), np.asarray(it1))


@needs_devices(2)
def test_sharded_iters_match_on_already_converged_init():
    """A row whose init is already a fixpoint (all-0̄, or isolated
    source) still burns the same first round as the single-device
    runner — iteration counts stay bit-identical, not merely values."""
    rel = _graph_rel("bool")
    n = rel.shape[0]
    mesh = make_graph_mesh(min(NDEV, 8))
    # batched: one inert all-0̄ padding row next to a live source row
    init = np.stack([np.zeros(n, bool), _init_for("bool", n, 0)])
    y0, it0 = sparse_seminaive_fixpoint(rel, init, mode="jit")
    y1, it1 = dd.sharded_seminaive_fixpoint(rel, init, mesh=mesh)
    assert np.array_equal(np.asarray(y0), np.asarray(y1))
    assert np.array_equal(np.asarray(it0), np.asarray(it1))
    # single-source all-0̄ init
    z0, iz0 = sparse_seminaive_fixpoint(rel, np.zeros(n, bool),
                                        mode="jit")
    z1, iz1 = dd.sharded_seminaive_fixpoint(rel, np.zeros(n, bool),
                                            mesh=mesh)
    assert np.array_equal(np.asarray(z0), np.asarray(z1))
    assert int(iz0) == int(iz1)


@needs_devices(2)
def test_sharded_resume_matches_full_recompute():
    """Warm-start repair after a monotone update: the sharded resume
    loop re-converges to exactly the from-scratch answer, batched."""
    rel = _graph_rel("trop")
    n = rel.shape[0]
    init = np.stack([_init_for("trop", n, s) for s in (0, 5)])
    mesh = make_graph_mesh(min(NDEV, 8))
    y_star, _ = sparse_seminaive_fixpoint(rel, init, mode="jit")
    coords = np.array([[2, 40], [40, 60], [60, 2]])
    values = np.ones(3, np.float32)
    delta = SparseRelation.from_coo(coords, values, rel.shape, "trop",
                                    lib="np")
    rel2 = rel.apply_delta(coords, values)
    d0 = delta_seed(delta, np.asarray(y_star), backend="np")
    yw, _ = dd.sharded_resume_fixpoint(
        dd.shard_relation(rel2, mesh), np.asarray(y_star), d0, mesh=mesh)
    y_full, _ = sparse_seminaive_fixpoint(rel2, init, mode="jit")
    yw_single, _ = resume_fixpoint(rel2, np.asarray(y_star), d0,
                                   mode="jit")
    assert np.array_equal(np.asarray(yw), np.asarray(y_full))
    assert np.array_equal(np.asarray(yw), np.asarray(yw_single))


@needs_devices(2)
def test_sharded_contract_nat():
    """ℕ∞ has no ⊖ (no GSN fixpoint) — the sharded exchange itself must
    still match the single-device contraction exactly."""
    rel = _graph_rel("bool")
    reln = SparseRelation.from_coo(
        rel.as_np().coords[:int(rel.as_np().nnz)],
        np.ones(int(rel.as_np().nnz), np.float32), rel.shape, "nat",
        lib="np")
    n = rel.shape[0]
    x = np.random.default_rng(3).random(n).astype(np.float32)
    mesh = make_graph_mesh(min(NDEV, 8))
    want = np.asarray(contract.vspm(x, reln.as_jnp()))
    got = np.asarray(dd.sharded_contract(reln, x, mesh=mesh))
    assert np.allclose(want, got, rtol=1e-6, atol=1e-5)
    with pytest.raises(ValueError, match="⊖"):
        dd.sharded_seminaive_fixpoint(reln, x, mesh=mesh)


@needs_devices(2)
def test_sharded_rejects_mismatched_d():
    rel = _graph_rel("bool")
    mesh = make_graph_mesh(2)
    sh = dd.shard_relation(rel, 4)
    with pytest.raises(ValueError, match="re-shard"):
        dd.sharded_seminaive_fixpoint(sh, _init_for("bool", rel.shape[0]),
                                      mesh=mesh)


# --------------------------------------------------------------------------
# serve loop integration
# --------------------------------------------------------------------------


@needs_devices(2)
def test_serve_graph_mesh_parity(monkeypatch):
    """A graph-mesh server answers queries and applies warm-repaired
    updates identically to a plain single-device server, with compiled
    runners keyed (signature, B-bucket, D).  The crossover floor is
    patched away so the 150-vertex toy graph still exercises the
    sharded serve path (real planning would keep it single-device)."""
    from repro.launch.datalog_serve import DatalogServer

    monkeypatch.setattr(planner.SHARDED_COST, "min_work_per_device", 0.0)
    monkeypatch.setattr(planner.SHARDED_COST, "sync_flops_per_device", 0.0)
    g = datasets.powerlaw(150, 3, seed=2)
    b0 = programs.bm(a=0)
    db = engine.Database(b0.original.schema, {"id": g.n},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((g.n,), bool)})
    d = min(NDEV, 8)
    srv = DatalogServer(max_batch=4, mesh=make_graph_mesh(d))
    srv0 = DatalogServer(max_batch=4)
    fam = srv.register("reach", lambda a: programs.bm(a=a).optimized, db)
    srv0.register("reach", lambda a: programs.bm(a=a).optimized, db)
    assert fam.plan.strata[0].runner == "sparse_sharded"
    assert fam.sharded is not None

    reqs = [srv.submit("reach", s) for s in (1, 4, 9)]
    reqs0 = [srv0.submit("reach", s) for s in (1, 4, 9)]
    srv.run_until_idle()
    srv0.run_until_idle()
    for r, r0 in zip(reqs, reqs0):
        assert r.error is None
        assert np.array_equal(r.result, r0.result)
        assert r.iters == r0.iters
    assert all(k[2] == d for k in srv._compiled)

    up = srv.submit_update("reach", [[1, 149], [149, 4]])
    up0 = srv0.submit_update("reach", [[1, 149], [149, 4]])
    r = srv.submit("reach", 1)
    r0 = srv0.submit("reach", 1)
    srv.run_until_idle()
    srv0.run_until_idle()
    assert up.applied and up0.applied
    assert np.array_equal(r.result, r0.result)
    assert srv.stats["answers_repaired"] == 3


# --------------------------------------------------------------------------
# Δ-sparse exchange ≡ dense all-gather reference (DESIGN.md §8)
# --------------------------------------------------------------------------


def _both_exchanges(rel, init, mesh, **kw):
    ya, ia = dd.sharded_seminaive_fixpoint(rel, init, mesh=mesh,
                                           exchange="auto", **kw)
    yd, id_ = dd.sharded_seminaive_fixpoint(rel, init, mesh=mesh,
                                            exchange="dense", **kw)
    assert np.array_equal(np.asarray(ya), np.asarray(yd))
    assert np.array_equal(np.asarray(ia), np.asarray(id_))
    return np.asarray(ya), np.asarray(ia)


@needs_devices(2)
@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_exchange_matches_dense_property(data):
    """Δ-sparse exchange ≡ the dense all-gather reference bit-for-bit:
    random graphs (ragged per-shard nnz, duplicate edges), bool/trop,
    single and batched (B, n) inits, D ∈ {2, NDEV}."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    semiring = data.draw(st.sampled_from(("bool", "trop")))
    n = data.draw(st.integers(8, 60))
    nnz = data.draw(st.integers(0, 150))
    d = data.draw(st.sampled_from((2, min(8, NDEV))))
    b = data.draw(st.sampled_from((0, 1, 3)))  # 0 = unbatched
    rel = _random_rel(rng, n, semiring, nnz)
    if b == 0:
        init = _init_for(semiring, n, source=int(rng.integers(0, n)))
    else:
        init = np.stack([
            _init_for(semiring, n, source=int(rng.integers(0, n)))
            for _ in range(b)])
    mesh = make_graph_mesh(d)
    y, it = _both_exchanges(rel, init, mesh)
    y1, it1 = sparse_seminaive_fixpoint(rel, init, mode="jit")
    assert np.array_equal(y, np.asarray(y1))
    assert np.array_equal(np.asarray(it), np.asarray(it1))


@needs_devices(2)
def test_exchange_matches_dense_maxplus_dag():
    """The third lattice semiring (longest path on a DAG), both packed
    and unbatched, across the exchange modes."""
    rel = _graph_rel("maxplus")
    n = rel.shape[0]
    mesh = make_graph_mesh(min(8, NDEV))
    _both_exchanges(rel, _init_for("maxplus", n), mesh)
    init = np.stack([_init_for("maxplus", n, source=s) for s in (0, 3)])
    y, _ = _both_exchanges(rel, init, mesh)
    y1, _ = sparse_seminaive_fixpoint(rel, init, mode="jit")
    assert np.array_equal(y, np.asarray(y1))


@needs_devices(2)
def test_exchange_fallback_boundary_rounds():
    """The density-threshold fallback boundary: tiny expansion caps
    force dense rounds, roomy caps keep every round sparse, and the
    round counters account for every derive — all bit-exact."""
    rel = _graph_rel("bool")
    n = rel.shape[0]
    init = _init_for("bool", n)
    mesh = make_graph_mesh(min(8, NDEV))
    sh = dd.shard_relation(rel, mesh)
    yd, itd = dd.sharded_seminaive_fixpoint(sh, init, mesh=mesh,
                                            exchange="dense")

    # expansion cap 1: any nonempty frontier overflows → dense fallback
    y, it, rounds = dd.sharded_seminaive_fixpoint_stats(
        sh, init, mesh=mesh, exchange_caps=((1, 1),))
    assert np.array_equal(np.asarray(y), np.asarray(yd))
    assert int(it) == int(itd)
    rounds = np.asarray(rounds)
    assert rounds.sum() == int(it) + 1  # cold derive + one per iteration
    assert rounds[-1] >= 1

    # roomy caps: every round stays on the sparse tier
    y2, it2, rounds2 = dd.sharded_seminaive_fixpoint_stats(
        sh, init, mesh=mesh,
        exchange_caps=((sh.row_block, sh.capacity),))
    assert np.array_equal(np.asarray(y2), np.asarray(yd))
    rounds2 = np.asarray(rounds2)
    assert rounds2[-1] == 0
    assert rounds2.sum() == int(it2) + 1

    report = dd.exchange_byte_report(sh, rounds2,
                                     exchange_caps=((sh.row_block,
                                                     sh.capacity),))
    assert report["rounds"] == rounds2.tolist()
    assert report["bytes_total"] > 0
    assert report["dense_bytes_per_iter"] == sh.n_pad * \
        dd.payload_row_bytes("bool", 1)


@needs_devices(2)
@pytest.mark.parametrize("d", [2, 8])
def test_exchange_warm_resume_matches_dense(d):
    """Warm resumes after apply_delta (which rebuilds the exchange
    geometry) agree across exchange modes and with a cold recompute."""
    if NDEV < d:
        pytest.skip(f"needs {d} devices")
    rel = _graph_rel("trop", n=72, seed=3)
    n = rel.shape[0]
    init = _init_for("trop", n)
    mesh = make_graph_mesh(d)
    sh = dd.shard_relation(rel, mesh)
    y0, _ = dd.sharded_seminaive_fixpoint(sh, init, mesh=mesh)
    coords = np.array([[0, n - 1], [n - 1, 5]])
    vals = np.ones(2, np.float32)
    sh2 = sh.apply_delta(coords, vals)
    delta = SparseRelation.from_coo(coords, vals, rel.shape, "trop",
                                    lib="np")
    d0 = delta_seed(delta, np.asarray(y0), backend="np")
    ya, ia = dd.sharded_resume_fixpoint(sh2, np.asarray(y0), d0,
                                        mesh=mesh, exchange="auto")
    yd, idn = dd.sharded_resume_fixpoint(sh2, np.asarray(y0), d0,
                                         mesh=mesh, exchange="dense")
    assert np.array_equal(np.asarray(ya), np.asarray(yd))
    assert int(ia) == int(idn)
    yf, _ = dd.sharded_seminaive_fixpoint(sh2, init, mesh=mesh)
    assert np.array_equal(np.asarray(ya), np.asarray(yf))


@needs_devices(2)
def test_exchange_without_geometry_falls_back_dense():
    """Relations lacking the cached exchange geometry (older pytrees,
    hand-built shards) silently run the dense reference path."""
    import dataclasses as dc

    rel = _graph_rel("bool")
    n = rel.shape[0]
    init = _init_for("bool", n)
    mesh = make_graph_mesh(min(8, NDEV))
    sh = dd.shard_relation(rel, mesh)
    bare = dc.replace(sh, ssrc=None, sdst=None, sval=None, usrc=None,
                      ustart=None)
    assert not bare.has_exchange_geometry
    y, it, rounds = dd.sharded_seminaive_fixpoint_stats(
        bare, init, mesh=mesh)
    yd, itd = dd.sharded_seminaive_fixpoint(sh, init, mesh=mesh,
                                            exchange="dense")
    assert np.array_equal(np.asarray(y), np.asarray(yd))
    assert int(it) == int(itd)
    assert np.asarray(rounds).tolist() == [int(it) + 1]

    plain = dd.shard_relation(rel, mesh, balance=False)
    y2, _ = dd.sharded_seminaive_fixpoint(plain, init, mesh=mesh)
    assert np.array_equal(np.asarray(y2), np.asarray(yd))


@needs_devices(2)
def test_exchange_contract_nat_with_balance():
    """ℕ∞ has no ⊖, so it only reaches the one-shot contract — which
    keeps the dense exchange but must invert the balance relabeling."""
    rng = np.random.default_rng(11)
    rel = _random_rel(rng, 50, "nat", 180)
    x = rng.random(50).astype(np.float32)
    mesh = make_graph_mesh(min(8, NDEV))
    got = dd.sharded_contract(rel, x, mesh=mesh)
    want = contract.vspm(jnp.asarray(x), rel.as_jnp())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5)
