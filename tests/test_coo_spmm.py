"""Fused COO semiring SpMM (DESIGN.md §9, ``kernels/coo_spmm.py``):

* Pallas kernel parity vs the jnp gather→⊗→segment-⊕ oracle across all
  four semirings, ragged nnz tails (empty / duplicate / off-block
  shapes), (B, n) batching, and both transpose orientations — in
  interpret mode so CI's CPU job exercises the kernel path
  (``make test-kernel`` runs this file under REPRO_PALLAS_INTERPRET=1).
* Host fused executors (``spmm_host``, packed-𝔹 ``bool_round_packed``)
  against the same oracle.
* Fixpoint parity — values AND per-row iteration counts — of the
  fused/pallas backends vs the jnp staged loop, single and batched,
  plus the warm resume-chunk carry the continuous serve loop compiles.
* Planner crossover pinning: ``sparse_frontier_pallas`` is picked
  exactly where ``SpmmKernelModel`` says the measured win exists, and
  rejected (with the right reason) everywhere else; monkeypatching the
  measured constants flips the pick at both extremes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, planner
from repro.core import semiring as sr_mod
from repro.core.program import run_program
from repro.datalog import datasets, programs
from repro.kernels import coo_spmm
from repro.kernels import ops as kops
from repro.sparse import contract
from repro.sparse.coo import SparseRelation
from repro.sparse.fixpoint import (resume_fixpoint_chunk,
                                   sparse_seminaive_fixpoint)

CPU = jax.default_backend() == "cpu"
SEMIRINGS = ("bool", "trop", "nat", "maxplus")


def _relation(n, avg_deg, sr_name, seed, lib="jnp"):
    g = datasets.powerlaw(n, avg_deg, seed=seed)
    rng = np.random.default_rng(seed + 1)
    edges = g.edges
    if sr_name == "maxplus":
        # longest-path diverges on cycles (⊕=max keeps growing); orient
        # low→high so the fixpoint converges in O(depth) rounds
        edges = np.sort(edges, axis=1)
        edges = edges[edges[:, 0] != edges[:, 1]]
    w = rng.integers(1, 5, len(edges))
    if sr_name == "bool":
        rel = datasets.Graph(n, edges, w).sparse_adjacency()
    else:
        rel = SparseRelation.from_coo(edges, w, (n, n), sr_name)
    return rel.as_jnp() if lib == "jnp" else rel


def _frontier(n, b, sr_name, seed, live_frac=0.1):
    rng = np.random.default_rng(seed)
    live = rng.random((n, b)) < live_frac
    srn = sr_mod.get(sr_name, lib="np")
    if sr_name == "bool":
        return live
    x = np.full((n, b), srn.zero, srn.dtype)
    x[live] = rng.integers(0, 8, int(live.sum())).astype(srn.dtype)
    return x


def _oracle(rel, x, transpose):
    xj = jnp.asarray(x)
    if xj.ndim == 1:  # the jnp oracle is the batched (n, B) contraction
        return np.asarray(contract.spmm(rel, xj[:, None],
                                        transpose=transpose))[:, 0]
    return np.asarray(contract.spmm(rel, xj, transpose=transpose))


# --------------------------------------------------------------------------
# Pallas kernel parity (interpret mode — the CI CPU path)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("sr_name", SEMIRINGS)
@pytest.mark.parametrize("transpose", [False, True])
def test_pallas_batched_parity(sr_name, transpose):
    n = 300  # off every block multiple: dot (256,256,128), minmax 32³
    rel = _relation(n, 3, sr_name, seed=11)
    plan = coo_spmm.plan_geometry(rel, transpose=transpose)
    x = _frontier(n, 8, sr_name, seed=5)
    got = np.asarray(coo_spmm.spmm_pallas(plan, x, interpret=True))
    assert np.array_equal(got, _oracle(rel, x, transpose)), sr_name


@pytest.mark.parametrize("sr_name", SEMIRINGS)
def test_pallas_single_vector_parity(sr_name):
    n = 130
    rel = _relation(n, 4, sr_name, seed=3)
    plan = coo_spmm.plan_geometry(rel, transpose=True)
    x = _frontier(n, 1, sr_name, seed=9)[:, 0]
    got = np.asarray(coo_spmm.spmm_pallas(plan, x, interpret=True))
    assert got.shape == (n,)
    assert np.array_equal(got, _oracle(rel, x, True))


@pytest.mark.parametrize("sr_name", ["bool", "trop"])
def test_pallas_empty_operator(sr_name):
    n = 64
    rel = SparseRelation.from_coo(np.zeros((0, 2), np.int64),
                                  np.zeros((0,)), (n, n), sr_name)
    plan = coo_spmm.plan_geometry(rel, transpose=True)
    assert plan.nnz == 0
    x = _frontier(n, 4, sr_name, seed=1)
    got = np.asarray(coo_spmm.spmm_pallas(plan, x, interpret=True))
    srn = sr_mod.get(sr_name, lib="np")
    assert np.array_equal(got, np.full((n, 4), srn.zero, srn.dtype))


@pytest.mark.parametrize("sr_name", ["trop", "nat"])
def test_pallas_duplicate_edges_coalesce(sr_name):
    """from_coo ⊕-coalesces duplicates; kernel and oracle must agree on
    the coalesced operator."""
    rng = np.random.default_rng(7)
    n = 80
    coords = rng.integers(0, n, (400, 2))  # heavy duplication
    vals = rng.integers(1, 6, 400)
    rel = SparseRelation.from_coo(coords, vals, (n, n), sr_name)
    plan = coo_spmm.plan_geometry(rel, transpose=True)
    x = _frontier(n, 8, sr_name, seed=2)
    got = np.asarray(coo_spmm.spmm_pallas(plan, x, interpret=True))
    assert np.array_equal(got, _oracle(rel, x, True))


def test_pallas_ragged_nnz_tail():
    """nnz far from a bk=256 multiple + n far from block multiples: pad
    slots must contribute the ⊕-identity, not junk."""
    rel = _relation(257, 5, "bool", seed=13)  # nnz ≈ 1285 = 5×257
    plan = coo_spmm.plan_geometry(rel, transpose=True)
    assert plan.nnz % plan.bk != 0
    x = _frontier(257, 3, "bool", seed=4)
    got = np.asarray(coo_spmm.spmm_pallas(plan, x, interpret=True))
    assert np.array_equal(got, _oracle(rel, x, True))


# --------------------------------------------------------------------------
# host fused executors
# --------------------------------------------------------------------------


@pytest.mark.parametrize("sr_name", SEMIRINGS)
@pytest.mark.parametrize("transpose", [False, True])
def test_spmm_host_parity(sr_name, transpose):
    n = 220
    rel = _relation(n, 4, sr_name, seed=21)
    plan = coo_spmm.plan_geometry(rel, transpose=transpose)
    x = _frontier(n, 8, sr_name, seed=6)
    got = coo_spmm.spmm_host(plan, x)
    assert np.array_equal(got, _oracle(rel, x, transpose))
    x1 = x[:, 0]
    got1 = coo_spmm.spmm_host(plan, x1)
    assert got1.shape == (n,)
    assert np.array_equal(got1, _oracle(rel, x1, transpose))


@pytest.mark.parametrize("b", [1, 8, 64, 70])
def test_bool_round_packed_parity(b):
    """Packed-𝔹 round across word boundaries: 1 lane, full word, exact
    multiple, and a ragged 2-word tail."""
    n = 220
    rel = _relation(n, 4, "bool", seed=21)
    plan = coo_spmm.plan_geometry(rel, transpose=True)
    x = _frontier(n, b, "bool", seed=b)
    words = coo_spmm.pack_lanes(x.T)
    assert words.shape == (n, max(1, -(-b // 64)))
    got = coo_spmm.unpack_lanes(
        coo_spmm.bool_round_packed(plan, words), b).T
    assert np.array_equal(got, _oracle(rel, x, True))


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    x = rng.random((70, 150)) < 0.3  # (B, n), B off a word boundary
    assert np.array_equal(
        coo_spmm.unpack_lanes(coo_spmm.pack_lanes(x), 70), x)


# --------------------------------------------------------------------------
# geometry plan discipline
# --------------------------------------------------------------------------


def test_plan_geometry_cached_per_operator():
    rel = _relation(100, 3, "bool", seed=1)
    p1 = coo_spmm.plan_geometry(rel, transpose=True)
    p2 = coo_spmm.plan_geometry(rel, transpose=True)
    assert p1 is p2
    assert coo_spmm.plan_geometry(rel, transpose=False) is not p1
    # as_jnp on a jnp-backed relation preserves buffer identity — the
    # serve loop's repeat calls must hit the same plan (and jit_cache)
    assert coo_spmm.plan_geometry(rel.as_jnp(), transpose=True) is p1


def test_plan_geometry_rejects_tracers():
    rel = _relation(50, 3, "bool", seed=2)

    @jax.jit
    def bad(coords, values):
        r = SparseRelation(coords, values, rel.shape, rel.semiring,
                           rel.nnz)
        coo_spmm.plan_geometry(r, transpose=True)
        return coords

    with pytest.raises(ValueError, match="concrete operator"):
        bad(rel.coords, rel.values)


# --------------------------------------------------------------------------
# fixpoint parity: values AND per-row iteration counts
# --------------------------------------------------------------------------


@pytest.mark.parametrize("sr_name", ["bool", "trop", "maxplus"])
@pytest.mark.parametrize("backend", ["fused", "pallas"])
def test_fixpoint_backend_parity_batched(sr_name, backend):
    n, b = 240, 6
    rel = _relation(n, 3, sr_name, seed=31)
    srn = sr_mod.get(sr_name, lib="np")
    init = np.full((b, n), srn.zero, srn.dtype)
    for i in range(b):
        init[i, (i * 17) % n] = srn.one
    want_x, want_it = sparse_seminaive_fixpoint(rel, jnp.asarray(init),
                                                mode="jit")
    got_x, got_it = sparse_seminaive_fixpoint(rel, jnp.asarray(init),
                                              mode="jit", backend=backend)
    assert np.array_equal(np.asarray(got_x), np.asarray(want_x)), sr_name
    assert np.array_equal(np.asarray(got_it), np.asarray(want_it))


@pytest.mark.parametrize("backend", ["fused", "pallas"])
def test_fixpoint_backend_parity_single(backend):
    n = 180
    rel = _relation(n, 3, "trop", seed=8)
    init = np.full(n, np.inf, np.float32)
    init[0] = 0.0
    want_x, want_it = sparse_seminaive_fixpoint(rel, jnp.asarray(init),
                                                mode="jit")
    got_x, got_it = sparse_seminaive_fixpoint(rel, jnp.asarray(init),
                                              mode="jit", backend=backend)
    assert np.array_equal(np.asarray(got_x), np.asarray(want_x))
    assert int(got_it) == int(want_it)


@pytest.mark.parametrize("backend", ["fused", "pallas"])
def test_resume_chunk_backend_parity(backend):
    """The serve loop's compiled unit: chained bounded chunks must carry
    (y, Δ, it) identically to the jnp chunk body."""
    n, b = 200, 5
    rel = _relation(n, 3, "bool", seed=41)
    init = np.zeros((b, n), bool)
    init[np.arange(b), np.arange(b) * 13] = True
    y_j = d_j = jnp.asarray(init)
    y_f, d_f = np.asarray(init), np.asarray(init)
    it_j = jnp.zeros(b, jnp.int32)
    it_f = np.zeros(b, np.int32)
    for _ in range(4):
        y_j, d_j, it_j = resume_fixpoint_chunk(rel, y_j, d_j, it_j,
                                               max_iters=3)
        y_f, d_f, it_f = resume_fixpoint_chunk(rel, y_f, d_f, it_f,
                                               max_iters=3,
                                               backend=backend)
        assert np.array_equal(np.asarray(y_f), np.asarray(y_j))
        assert np.array_equal(np.asarray(d_f), np.asarray(d_j))
        assert np.array_equal(np.asarray(it_f), np.asarray(it_j))


# --------------------------------------------------------------------------
# planner crossover pinning (both extremes)
# --------------------------------------------------------------------------


def _bool_plan(n, objective="throughput", avg_deg=3.0):
    g = datasets.erdos_renyi(n, avg_deg, seed=2)
    schema = programs.bm(a=0).original.schema
    db = engine.Database(schema, {"id": n},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((n,), bool)})
    return planner.plan_program(programs.bm(a=0).optimized, db,
                                objective=objective)


def _trop_plan(n, objective="throughput", avg_deg=3.0):
    b = programs.sssp(a=0, wmax=4, dmax=40)
    g = datasets.erdos_renyi(n, avg_deg, seed=4, weighted=True, wmax=4)
    db = engine.Database(b.original.schema, {"id": n, "w": 4, "d": 40}, {})
    return planner.plan_program(b.optimized, db, objective=objective,
                                edges=g.sparse_adjacency(semiring="trop"))


@pytest.mark.skipif(not CPU, reason="crossover constants are per-host; "
                                    "the pinned picks assume CPU")
def test_planner_picks_pallas_above_crossover():
    sp = _bool_plan(5000).strata[0]
    assert sp.runner == "sparse_frontier_pallas", sp.considered
    assert "sparse_frontier_pallas" in sp.considered


@pytest.mark.skipif(not CPU, reason="crossover constants are per-host")
def test_planner_rejects_below_crossover():
    sp = _bool_plan(200).strata[0]
    assert sp.runner != "sparse_frontier_pallas"
    assert "below the fused-kernel crossover" in \
        sp.rejected["sparse_frontier_pallas"]


@pytest.mark.skipif(not CPU, reason="crossover constants are per-host")
def test_planner_rejects_latency_objective():
    sp = _bool_plan(5000, objective="latency").strata[0]
    assert sp.runner != "sparse_frontier_pallas"
    assert "batched-serving backend" in \
        sp.rejected["sparse_frontier_pallas"]


@pytest.mark.skipif(not CPU, reason="crossover constants are per-host")
def test_planner_rejects_semiring_without_measured_win():
    """trop measured slower fused than jnp on CPU — that IS the
    crossover (SpmmKernelModel.host_speedup has no trop entry)."""
    sp = _trop_plan(2000).strata[0]
    assert sp.runner != "sparse_frontier_pallas"
    assert "no measured fused-kernel win" in \
        sp.rejected["sparse_frontier_pallas"]


@pytest.mark.skipif(not CPU, reason="crossover constants are per-host")
def test_planner_pick_flips_with_measured_constants(monkeypatch):
    """The pick is pinned to SpmmKernelModel, not hardcoded: grant trop
    a measured win and it flips in; revoke bool's and it flips out."""
    monkeypatch.setitem(planner.SPMM_COST.host_speedup, "trop", 5.0)
    sp = _trop_plan(2000).strata[0]
    assert sp.runner == "sparse_frontier_pallas", sp.rejected
    monkeypatch.setitem(planner.SPMM_COST.host_speedup, "bool", 0.0)
    sp = _bool_plan(5000).strata[0]
    assert sp.runner != "sparse_frontier_pallas"
    assert "no measured fused-kernel win" in \
        sp.rejected["sparse_frontier_pallas"]


@pytest.mark.skipif(not CPU, reason="crossover constants are per-host")
def test_pallas_plan_answers_match_naive(monkeypatch):
    """End-to-end: the sparse_frontier_pallas plan's answers (and its
    compile_batched unit) are bit-exact vs the jnp runners.  The
    crossover floor is lowered so the cell stays small enough for
    interpret mode (REPRO_PALLAS_INTERPRET CI runs execute the kernel
    path here, not the host loop)."""
    monkeypatch.setattr(planner.SPMM_COST, "min_nnz", 1024.0)
    n = 800
    g = datasets.erdos_renyi(n, 3.0, seed=2)
    schema = programs.bm(a=0).original.schema
    db = engine.Database(schema, {"id": n},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((n,), bool)})
    b = programs.bm(a=0)
    plan = planner.plan_program(b.optimized, db, objective="throughput")
    assert plan.strata[0].runner == "sparse_frontier_pallas"
    got, _ = run_program(b.optimized, db, plan=plan)
    ref, _ = run_program(b.optimized, db, mode="seminaive")
    assert np.array_equal(np.asarray(got), np.asarray(ref))
    # the batched serve unit off the same plan
    rel = db.relations["E"].as_jnp()
    init = np.zeros((4, n), bool)
    init[np.arange(4), np.arange(4)] = True
    run = planner.compile_batched(plan, max_iters=10_000)
    x_b, it_b = run(rel, jnp.asarray(init))
    x_r, it_r = sparse_seminaive_fixpoint(rel, jnp.asarray(init),
                                          mode="jit")
    assert np.array_equal(np.asarray(x_b), np.asarray(x_r))
    assert np.array_equal(np.asarray(it_b), np.asarray(it_r))


def test_spmm_exec_backend_resolution(monkeypatch):
    assert planner.spmm_exec_backend("sparse_jit") == "jnp"
    assert planner.spmm_exec_backend("sparse_sharded") == "jnp"
    monkeypatch.setattr(kops, "_FORCE_INTERPRET", True)
    assert planner.spmm_exec_backend("sparse_frontier_pallas") == "pallas"
    if CPU:
        monkeypatch.setattr(kops, "_FORCE_INTERPRET", False)
        assert planner.spmm_exec_backend("sparse_frontier_pallas") \
            == "fused"
