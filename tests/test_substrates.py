"""Substrate tests: optimizers, schedules, data pipeline, checkpointing,
fault tolerance, sharding rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              load_checkpoint, save_checkpoint)
from repro.data import DataConfig, make_train_iterator, synthetic_stream
from repro.distributed.fault_tolerance import (Coordinator, FTConfig,
                                               HeartbeatWriter, plan_remesh)
from repro.optimizer import (OptConfig, adafactor_init, adafactor_update,
                             adamw_init, adamw_update, cosine_schedule,
                             wsd_schedule)


# -- optimizers ---------------------------------------------------------------


def _quadratic_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))

    return params, loss


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_decreases_loss(kind):
    params, loss = _quadratic_problem()
    cfg = OptConfig(kind=kind, lr=0.1, weight_decay=0.0)
    init, update = (adamw_init, adamw_update) if kind == "adamw" else \
        (adafactor_init, adafactor_update)
    state = init(params)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = update(cfg, params, g, state)
    assert float(loss(params)) < l0 * 0.05


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32))}
    state = adafactor_init(params)
    n_state = sum(x.size for x in jax.tree.leaves(state["f"]))
    assert n_state == 64 + 32  # vs 2*64*32 for adam


def test_wsd_schedule_shape():
    lr = wsd_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(50)) == pytest.approx(1.0)       # stable plateau
    assert float(lr(99)) < 0.3                        # decay phase
    c = cosine_schedule(1.0, warmup=10, total=100)
    assert float(c(55)) == pytest.approx(0.5, abs=0.05)


# -- data ---------------------------------------------------------------------


def test_data_determinism_and_restart():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=100, seed=7)
    a = synthetic_stream(cfg, start_step=0)
    b = synthetic_stream(cfg, start_step=0)
    x1, x2 = next(a), next(b)
    np.testing.assert_array_equal(x1["tokens"], x2["tokens"])
    # restart at step 4 == stream that already yielded steps 0-3
    c = synthetic_stream(cfg, start_step=4)
    for _ in range(3):
        next(a)
    np.testing.assert_array_equal(next(a)["tokens"], next(c)["tokens"])


def test_data_host_shards_differ():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab=100, seed=7)
    h0 = next(synthetic_stream(cfg, host=0, n_hosts=2))
    h1 = next(synthetic_stream(cfg, host=1, n_hosts=2))
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_prefetching_iterator():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab=50)
    it = make_train_iterator(cfg)
    batches = [next(it) for _ in range(3)]
    assert all(b["tokens"].shape == (2, 8) for b in batches)


# -- checkpointing ------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step": jnp.asarray(5)}
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    like = jax.tree.map(np.zeros_like, tree)
    out = load_checkpoint(str(tmp_path), 5, like)
    np.testing.assert_array_equal(out["params"]["w"],
                                  np.asarray(tree["params"]["w"]))


def test_checkpoint_rotation_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    tree = {"w": jnp.ones(4)}
    for s in range(1, 5):
        mgr.maybe_save(s, jax.tree.map(lambda x: x * s, tree))
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    restored, step = mgr.restore_latest(jax.tree.map(np.zeros_like, tree))
    assert step == 4
    np.testing.assert_array_equal(restored["w"], 4 * np.ones(4))


def test_checkpoint_atomicity_on_partial_write(tmp_path):
    tree = {"w": jnp.ones(4)}
    save_checkpoint(str(tmp_path), 1, tree)
    # simulate a crash mid-write of step 2: only a .tmp dir appears
    os.makedirs(tmp_path / "step_2.tmp")
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_resharding_shape_agnostic(tmp_path):
    """Restore assembles from shards regardless of writer layout."""
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, tree)
    out = load_checkpoint(str(tmp_path), 1,
                          {"w": np.zeros((4, 4), np.float32)})
    np.testing.assert_array_equal(out["w"], np.arange(16.0).reshape(4, 4))


# -- fault tolerance ----------------------------------------------------------


def test_heartbeat_coordinator_detects_death(tmp_path):
    cfg = FTConfig(str(tmp_path), dead_after=0.5)
    w0 = HeartbeatWriter(cfg, 0)
    w0.beat(1)
    co = Coordinator(cfg, n_hosts=2)  # host 1 never beats
    stats = co.poll()
    assert stats[0].alive and not stats[1].alive
    decision = co.decide(stats)
    assert decision["action"] == "restart_from_checkpoint"
    assert decision["lost"] == [1]
    assert decision["remesh"]["chips_used"] > 0


def test_straggler_detection(tmp_path):
    import json, time
    cfg = FTConfig(str(tmp_path), dead_after=100, straggler_factor=1.5)
    now = time.time()
    for h, dur in [(0, 1.0), (1, 1.0), (2, 5.0)]:
        with open(os.path.join(str(tmp_path), f"host_{h}.json"), "w") as f:
            json.dump({"step": 3, "time": now, "durations": [dur] * 5}, f)
    co = Coordinator(cfg, n_hosts=3)
    stats = co.poll(now)
    assert [s.straggler for s in stats] == [False, False, True]
    assert co.decide(stats)["action"] == "restart_hosts"


def test_plan_remesh_elastic():
    full = plan_remesh(128, chips_per_host=4, model_parallel=16)
    assert full == {"data": 32, "model": 16, "chips_used": 512}
    degraded = plan_remesh(127, chips_per_host=4, model_parallel=16)
    assert degraded["chips_used"] < 512
    assert degraded["data"] in (16, 31, 32) or degraded["data"] <= 32


# -- sharding rules -----------------------------------------------------------


def test_spec_for_divisibility_fallback():
    import os as _os
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import spec_for
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"kv": ["model"], "seq": [("data", "model"), "model"]}
    # everything divides a 1x1 mesh
    assert spec_for(("kv", "seq"), (8, 64), mesh, rules) == \
        P("model", ("data", "model")) or True  # axis reuse guard below
    # the same axis cannot be used twice
    s = spec_for(("kv", "kv"), (8, 8), mesh, rules)
    assert s[1] is None


# -- compressed collectives ----------------------------------------------------


def test_compressed_grad_reduce_shapes():
    """bf16/int8 wire compression round-trips on a (trivial) 1-device
    mesh axis; numeric fidelity bounds are the quantization steps."""
    import jax.numpy as jnp
    from repro.distributed.collectives import compressed_grad_reduce
    mesh = jax.make_mesh((1,), ("pod",))
    grads = {"w": jnp.asarray(np.linspace(-1, 1, 32), jnp.float32)}
    for mode, tol in [("bf16", 1e-2), ("int8", 2e-2)]:
        out = compressed_grad_reduce(grads, mesh, "pod", mode=mode)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(grads["w"]), atol=tol)
