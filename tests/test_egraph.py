"""E-graph (EQSAT) tests: congruence, saturation, constrained equivalence,
denormalization-style extraction (paper Sec. 7)."""

from repro.core.egraph import (EGraph, ENode, SEMIRING_RULES,
                               equivalent_under)


def test_congruence_closure():
    g = EGraph()
    a = g.add_term("a")
    b = g.add_term("b")
    fa = g.add_term(("f", "a"))
    fb = g.add_term(("f", "b"))
    assert not g.eq(fa, fb)
    g.merge(a, b)
    g.rebuild()
    assert g.eq(fa, fb)  # f(a) = f(b) once a = b


def test_distributivity_saturation():
    # a⊗(b⊕c) ≡ a⊗b ⊕ a⊗c
    assert equivalent_under(
        SEMIRING_RULES,
        ("mul", "a", ("add", "b", "c")),
        ("add", ("mul", "a", "b"), ("mul", "a", "c")))


def test_commutativity_and_identity():
    assert equivalent_under(SEMIRING_RULES, ("mul", "a", "one"), "a")
    assert equivalent_under(SEMIRING_RULES, ("mul", "a", "b"),
                            ("mul", "b", "a"))
    assert not equivalent_under(SEMIRING_RULES, ("mul", "a", "b"),
                                ("mul", "a", "c"))


def test_equivalence_under_constraint():
    """Sec. 7: a constraint Δ ⇒ Θ becomes Δ∧Θ = Δ; here E∧T = E (E ⊆ T)
    makes (E∧T)∧x equivalent to E∧x."""
    constraint = [(("mul", "E", "T"), "E")]
    assert equivalent_under(SEMIRING_RULES,
                            ("mul", ("mul", "E", "T"), "x"),
                            ("mul", "E", "x"), constraints=constraint)
    assert not equivalent_under(SEMIRING_RULES,
                                ("mul", ("mul", "E", "T"), "x"),
                                ("mul", "E", "x"))


def test_denormalization_extraction():
    """Rewriting using views: replace the view's e-class with symbol Y and
    extract an X-free expression (paper Sec. 6.1 / Fig. 6 green box)."""
    g = EGraph()
    # normalized P1 = (X⊗E) ⊕ B ; view V = X⊗E
    p1 = g.add_term(("add", ("mul", "X", "E"), "B"))
    view = g.add_term(("mul", "X", "E"))
    y = g.add_term("Y")
    g.merge(view, y)
    g.rebuild()
    g.run_rules(SEMIRING_RULES, iters=4)
    out = g.extract(p1, forbid_ops={"X"})
    assert out is not None
    flat = str(out)
    assert "X" not in flat and "Y" in flat  # H = Y ⊕ B


def test_extraction_respects_cost():
    g = EGraph()
    big = g.add_term(("mul", ("mul", "a", "one"), "one"))
    g.run_rules(SEMIRING_RULES, iters=4)
    assert g.extract(big) == "a"
