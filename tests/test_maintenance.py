"""Synthesized ⊖/recount maintenance (DESIGN.md §11): CEGIS outcomes,
randomized differential checks against from-scratch ground truth, the
planner's synth_maintenance candidate, and the serve loop's warm-answer
repair on deletes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import egraph, engine, planner
from repro.core.program import run_program
from repro.datalog import datasets, programs
from repro.incremental import (DeltaLog, cached_rule, ensure_rule,
                               maintain_nonmonotone, refresh_program,
                               synthesize_maintenance)
from repro.incremental.maintenance import (MaintenanceRule, _gather_values,
                                           clear_rule_cache, rule_term)
from repro.sparse.coo import SparseRelation
from repro.sparse.fixpoint import fixpoint
from repro.core import semiring as sr_mod

LATTICES = ("bool", "trop", "maxplus")


def _random_rel(rng, n, semiring, avg_deg=2.5):
    """Random digraph as an np-lib SparseRelation; DAG for maxplus
    (positive cycles have no finite longest path)."""
    p = min(1.0, avg_deg / n)
    adj = rng.random((n, n)) < p
    np.fill_diagonal(adj, False)
    if semiring == "maxplus":
        adj = np.triu(adj)
    coords = np.argwhere(adj).astype(np.int64)
    sr = sr_mod.get(semiring, lib="np")
    values = (np.ones(len(coords), sr.dtype) if semiring == "bool"
              else rng.integers(1, 6, len(coords)).astype(sr.dtype))
    return SparseRelation.from_coo(coords, values, (n, n), semiring,
                                   lib="np")


def _one_hot(n, src, semiring):
    sr = sr_mod.get(semiring, lib="np")
    init = np.full(n, sr.zero, sr.dtype)
    init[src] = sr.one
    return init


def _live_edges(rel):
    h = rel.as_np()
    return np.asarray(h.coords[:int(h.nnz)]), np.asarray(
        h.values[:int(h.nnz)])


# --------------------------------------------------------------------------
# CEGIS outcomes
# --------------------------------------------------------------------------


@pytest.mark.parametrize("semiring", LATTICES)
def test_cegis_delete_winner_is_supported_tight(semiring):
    rule = synthesize_maintenance(semiring, "delete")
    assert rule.verified
    assert (rule.seeds, rule.cone) == ("supported", "tight")
    assert rule.name == "⊖-recount[seed=supported, cone=tight]"
    assert rule.probes > 0
    # every cheaper candidate was refuted by a concrete counterexample
    # (chains kill the no-closure cones; cycles kill DRed counting)
    refuted = {(s, c) for s, c, _ in rule.refuted}
    assert ("supported", "seeds") in refuted
    assert ("supported", "one_hop") in refuted


@pytest.mark.parametrize("semiring", ("bool", "trop"))
def test_cyclic_probes_refute_dred_counting(semiring):
    """DRed-style support counting (seed=unsupported) is unsound on
    cyclic support: the cheapest-first winner shadows it in the normal
    enumeration, so replay it directly — a cyclic probe must fail it."""
    from repro.core import verify
    from repro.incremental.maintenance import _first_failure
    cand = MaintenanceRule("unsupported", "tight", semiring, "delete",
                           False, "", rule_term("unsupported", "tight"))
    pool = verify.sample_update_probes(semiring,
                                       np.random.default_rng(0), 8)
    bad = _first_failure(cand, pool)
    assert bad is not None
    assert "cycle" in bad.name or "loop" in bad.name


def test_cegis_records_failure_without_minus():
    rule = synthesize_maintenance("nat", "delete")
    assert not rule.verified
    assert "⊖" in rule.reason
    with pytest.raises(ValueError, match="unverified"):
        maintain_nonmonotone(
            _random_rel(np.random.default_rng(0), 8, "bool"),
            np.zeros((0, 2), np.int64), np.zeros(0),
            _one_hot(8, 0, "bool"), _one_hot(8, 0, "bool"), rule)


def test_cegis_increase_rules():
    # ⊕ = max absorbs a weight increase's *lost* derivations only at the
    # touched edge itself — CEGIS discovers no closure is needed
    up = synthesize_maintenance("maxplus", "increase")
    assert up.verified and up.cone == "seeds"
    # trop ⊕ = min: an increase can unseat downstream minima — the same
    # tight closure as deletion wins
    tr = synthesize_maintenance("trop", "increase")
    assert tr.verified and (tr.seeds, tr.cone) == ("supported", "tight")
    bl = synthesize_maintenance("bool", "increase")
    assert not bl.verified


def test_egraph_rejects_full_cone_by_proof():
    for seeds in ("supported", "touched", "unsupported"):
        assert egraph.normalize(
            rule_term(seeds, "all")) == "cold_fixpoint"
    rule = synthesize_maintenance("bool", "delete")
    assert all("egraph" in why for s, c, why in rule.refuted
               if c == "all")


def test_rule_cache_round_trip():
    clear_rule_cache()
    assert cached_rule("sig-x", "trop", "delete") is None
    r1 = ensure_rule("sig-x", "trop", "delete")
    assert r1.verified
    assert cached_rule("sig-x", "trop", "delete") is r1
    assert ensure_rule("sig-x", "trop", "delete") is r1
    clear_rule_cache()


# --------------------------------------------------------------------------
# Randomized differential: maintenance ≡ from-scratch
# --------------------------------------------------------------------------


@pytest.mark.parametrize("semiring", LATTICES)
def test_differential_random_deletes(semiring):
    rule = synthesize_maintenance(semiring, "delete")
    rng = np.random.default_rng(7)
    for trial in range(12):
        n = int(rng.integers(8, 40))
        rel = _random_rel(rng, n, semiring)
        coords, vals = _live_edges(rel)
        if len(coords) < 2:
            continue
        init = _one_hot(n, int(rng.integers(n)), semiring)
        y_star, _ = fixpoint(rel, init, mode="frontier")
        k = int(rng.integers(1, min(6, len(coords))))
        sel = rng.choice(len(coords), k, replace=False)
        new = rel.delete_keys(coords[sel])
        y_true, _ = fixpoint(new, init, mode="frontier")
        y_got, _ = maintain_nonmonotone(new, coords[sel], vals[sel],
                                        np.asarray(y_star), init, rule)
        assert np.array_equal(np.asarray(y_got), np.asarray(y_true)), \
            (semiring, trial, n, coords[sel])


def test_differential_increase_trop():
    rule = synthesize_maintenance("trop", "increase")
    rng = np.random.default_rng(11)
    for trial in range(8):
        n = int(rng.integers(8, 30))
        rel = _random_rel(rng, n, "trop")
        coords, vals = _live_edges(rel)
        if len(coords) < 2:
            continue
        init = _one_hot(n, int(rng.integers(n)), "trop")
        y_star, _ = fixpoint(rel, init, mode="frontier")
        k = int(rng.integers(1, min(4, len(coords))))
        sel = rng.choice(len(coords), k, replace=False)
        bigger = vals[sel] + rng.integers(1, 5, k)
        new = rel.delete_keys(coords[sel]).apply_delta(coords[sel],
                                                       bigger)
        merge = SparseRelation.from_coo(coords[sel], bigger, rel.shape,
                                        "trop", lib="np")
        y_true, _ = fixpoint(new, init, mode="frontier")
        y_got, _ = maintain_nonmonotone(new, coords[sel], vals[sel],
                                        np.asarray(y_star), init, rule,
                                        merge_delta=merge)
        assert np.array_equal(np.asarray(y_got), np.asarray(y_true)), \
            (trial, n)


def test_delete_then_reinsert_round_trips():
    """Delete a batch, repair, re-insert the same edges, repair again
    (monotone leg) — lands exactly back on the original fixpoint."""
    from repro.incremental import delta_restart_fixpoint
    rule = synthesize_maintenance("trop", "delete")
    rng = np.random.default_rng(3)
    rel = _random_rel(rng, 25, "trop")
    coords, vals = _live_edges(rel)
    init = _one_hot(25, 0, "trop")
    y_star, _ = fixpoint(rel, init, mode="frontier")
    sel = rng.choice(len(coords), 3, replace=False)
    shrunk = rel.delete_keys(coords[sel])
    y_del, _ = maintain_nonmonotone(shrunk, coords[sel], vals[sel],
                                    np.asarray(y_star), init, rule)
    back = shrunk.apply_delta(coords[sel], vals[sel])
    delta = SparseRelation.from_coo(coords[sel], vals[sel], rel.shape,
                                    "trop", lib="np")
    y_back, _ = delta_restart_fixpoint(back, delta, np.asarray(y_del),
                                       mode="frontier")
    assert np.array_equal(np.asarray(y_back), np.asarray(y_star))


def test_batched_matches_per_row():
    rule = synthesize_maintenance("trop", "delete")
    rng = np.random.default_rng(5)
    rel = _random_rel(rng, 30, "trop", avg_deg=3.0)
    coords, vals = _live_edges(rel)
    sel = rng.choice(len(coords), 4, replace=False)
    new = rel.delete_keys(coords[sel])
    sources = (0, 7, 19)
    prev = np.stack([np.asarray(fixpoint(rel, _one_hot(30, s, "trop"),
                                         mode="frontier")[0])
                     for s in sources])
    init = np.stack([_one_hot(30, s, "trop") for s in sources])
    yb, ib = maintain_nonmonotone(new, coords[sel], vals[sel], prev,
                                  init, rule)
    for i, s in enumerate(sources):
        y1, i1 = maintain_nonmonotone(new, coords[sel], vals[sel],
                                      prev[i], init[i], rule)
        assert np.array_equal(np.asarray(yb)[i], np.asarray(y1)), s
        assert int(np.asarray(ib)[i]) == int(np.asarray(i1)), s


# --------------------------------------------------------------------------
# refresh_program: end-to-end, mixed streams, fallbacks
# --------------------------------------------------------------------------


def _bm_setup(n=40, seed=2):
    g = datasets.erdos_renyi(n, 2.0, seed=seed)
    db = engine.Database(programs.bm(a=0).original.schema, {"id": n},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((n,), bool)})
    return programs.bm(a=0).optimized, db


def test_refresh_delete_end_to_end():
    prog, db = _bm_setup()
    prev, _ = run_program(prog, db)
    eh = db.relations["E"].as_np()
    dels = np.asarray(eh.coords[:3])
    y, db2, rep = refresh_program(prog, db, np.asarray(prev),
                                  DeltaLog().delete("E", dels))
    assert rep.strategy == "synth_maintenance"
    assert "⊖-recount[seed=supported, cone=tight]" in rep.reason
    y_true, _ = run_program(prog, db2)
    assert np.array_equal(np.asarray(y), np.asarray(y_true))


def test_refresh_mixed_delete_and_insert():
    prog, db = _bm_setup(seed=9)
    prev, _ = run_program(prog, db)
    eh = db.relations["E"].as_np()
    dels = np.asarray(eh.coords[:2])
    log = DeltaLog().delete("E", dels).insert("E", [[1, 37], [37, 3]])
    y, db2, rep = refresh_program(prog, db, np.asarray(prev), log)
    assert rep.strategy == "synth_maintenance"
    y_true, _ = run_program(prog, db2)
    assert np.array_equal(np.asarray(y), np.asarray(y_true))


def test_refresh_falls_back_when_synthesis_fails():
    prog, db = _bm_setup()
    prev, _ = run_program(prog, db)
    clear_rule_cache()
    _, _, rep = refresh_program(prog, db, np.asarray(prev),
                                DeltaLog().delete("E", [[0, 1]]),
                                synth_budget_s=0.0)
    assert rep.strategy == "full"
    clear_rule_cache()


# --------------------------------------------------------------------------
# Planner: the synth_maintenance candidate
# --------------------------------------------------------------------------


def test_planner_prices_cached_rule_only():
    prog, db = _bm_setup(n=200, seed=5)
    clear_rule_cache()
    plan = planner.plan_program(prog, db, objective="incremental",
                                delta_nnz=2, delta_op="delete")
    sp = plan.strata[0]
    # planning never synthesizes: no cached rule → rejection, not a run
    assert sp.runner != "synth_maintenance"
    assert "no maintenance rule cached" in sp.rejected["synth_maintenance"]
    assert "non-monotone" in sp.rejected["delta_restart"]

    ensure_rule(sp.vf.signature, sp.vf.semiring, "delete")
    plan = planner.plan_program(prog, db, objective="incremental",
                                delta_nnz=2, delta_op="delete")
    sp = plan.strata[0]
    assert sp.runner == "synth_maintenance"
    assert "⊖-recount[seed=supported, cone=tight]" in sp.reason
    assert "⊖-recount" in planner.explain(plan)

    # a monotone merge must keep pricing delta-restart instead
    plan = planner.plan_program(prog, db, objective="incremental",
                                delta_nnz=2, delta_op="merge")
    sp = plan.strata[0]
    assert sp.runner == "delta_restart"
    assert "synth_maintenance" in sp.rejected
    clear_rule_cache()


# --------------------------------------------------------------------------
# Serve loop: deletes repair warm answers, compiled runners survive
# --------------------------------------------------------------------------


def test_serve_delete_repairs_and_keeps_compile_cache():
    from repro.launch.datalog_serve import DatalogServer
    n = 60
    g = datasets.erdos_renyi(n, 2.5, seed=4)
    db = engine.Database(programs.bm(a=0).original.schema, {"id": n},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((n,), bool)})
    server = DatalogServer(max_batch=4)
    fam = server.register("reach", lambda a: programs.bm(a=a).optimized,
                          db)
    sig0 = fam.plan.signature
    for s in (0, 11, 23):
        server.submit("reach", s)
    server.run_until_idle()
    misses0 = server.stats["cache_misses"]
    eh = db.relations["E"].as_np()
    u = server.submit_update("reach", np.asarray(eh.coords[:2]),
                             op="delete")
    reqs = [server.submit("reach", s) for s in (0, 11, 23)]
    server.run_until_idle()
    assert u.applied
    assert server.stats["answers_dropped"] == 0
    assert server.stats["answers_repaired"] >= 3
    assert server.stats["cache_misses"] == misses0, \
        "the delete re-lowered the staged fixpoint"
    assert fam.plan.signature == sig0
    db2 = engine.Database(db.schema, db.domains,
                          {"E": db.relations["E"].delete_keys(
                              np.asarray(eh.coords[:2])),
                           "V": db.relations["V"]})
    dense = db2.with_storage("E", "dense")
    for req in reqs:
        exp, _ = run_program(programs.bm(a=req.source).optimized, dense,
                             mode="seminaive")
        assert np.array_equal(req.result, np.asarray(exp)), req.source
