"""Regression tests for the adaptive density switch
(`repro.sparse.adaptive`): densify→sparsify round-trips under the
hysteresis thresholds preserve exact relation contents, and a density
sequence straddling the switch point never makes the representation
oscillate."""

import numpy as np
import pytest

from repro.core import ir, engine
from repro.core import semiring as sr_mod
from repro.sparse import (DENSIFY_ABOVE, SPARSIFY_BELOW, SparseRelation,
                          adapt_value, density)

SEMIRINGS = ["bool", "trop", "maxplus", "nat", "real"]


def _dense_at_density(sr_name: str, d: float, shape=(24, 24), seed=0):
    """A host array with an exact live fraction of ``d``."""
    sr = sr_mod.get(sr_name, lib="np")
    rng = np.random.default_rng(seed)
    n = int(np.prod(shape))
    k = int(round(d * n))
    arr = np.full(n, sr.zero, sr.dtype)
    idx = rng.choice(n, size=k, replace=False)
    if sr_name == "bool":
        arr[idx] = True
    else:
        arr[idx] = rng.integers(1, 5, k).astype(sr.dtype)
    return arr.reshape(shape)


def _contents(arr, sr_name: str) -> np.ndarray:
    return np.asarray(arr.to_dense() if isinstance(arr, SparseRelation)
                      else arr)


@pytest.mark.parametrize("sr_name", SEMIRINGS)
@pytest.mark.parametrize("d", [0.0, 0.01, 0.04, 0.10, 0.30])
def test_round_trip_preserves_contents(sr_name, d):
    """dense → adapt → (maybe sparse) → adapt → … keeps the exact
    relation contents at every step, at densities below, inside, and
    above the hysteresis band."""
    base = _dense_at_density(sr_name, d)
    cur = base
    for _ in range(4):
        cur = adapt_value(cur, sr_name)
        assert np.array_equal(_contents(cur, sr_name), base)


@pytest.mark.parametrize("sr_name", ["bool", "trop", "nat"])
def test_explicit_round_trip_exact(sr_name):
    """from_dense → to_dense is exact (coalescing, zero-dropping and the
    padding sentinel never alter live tuples)."""
    base = _dense_at_density(sr_name, 0.07, seed=3)
    rel = SparseRelation.from_dense(base, sr_name)
    assert np.array_equal(np.asarray(rel.to_dense()), base)
    # and density agrees between representations
    assert density(rel, sr_name) == pytest.approx(
        density(base, sr_name), abs=1e-9)


@pytest.mark.parametrize("sr_name", ["bool", "trop"])
def test_hysteresis_band_keeps_representation(sr_name):
    """Inside the (SPARSIFY_BELOW, DENSIFY_ABOVE) band the current
    representation always wins — from either side."""
    mid = (SPARSIFY_BELOW + DENSIFY_ABOVE) / 2
    dense_mid = _dense_at_density(sr_name, mid)
    assert not isinstance(adapt_value(dense_mid, sr_name), SparseRelation)
    sparse_mid = SparseRelation.from_dense(dense_mid, sr_name)
    assert isinstance(adapt_value(sparse_mid, sr_name), SparseRelation)


@pytest.mark.parametrize("sr_name", ["bool", "trop"])
def test_no_oscillation_straddling_the_switch_point(sr_name):
    """Walk a density sequence that repeatedly straddles the sparsify
    threshold *inside the band*: representation must flip only when an
    outer threshold is actually crossed — 3 flips for the full sweep,
    none during the straddles."""
    seq = [0.04, 0.10, 0.20, 0.10, 0.20, 0.10,      # straddle mid-band
           0.26,                                     # -> dense
           0.20, 0.10, 0.20, 0.10,                   # straddle again
           0.04]                                     # -> sparse
    cur = _dense_at_density(sr_name, seq[0])
    flips = []
    for i, d in enumerate(seq):
        was_sparse = isinstance(cur, SparseRelation)
        fresh = _dense_at_density(sr_name, d, seed=i)
        cur = (SparseRelation.from_dense(fresh, sr_name)
               if was_sparse else fresh)
        cur = adapt_value(cur, sr_name)
        if isinstance(cur, SparseRelation) != was_sparse:
            flips.append((i, d))
    assert flips == [(0, 0.04), (6, 0.26), (11, 0.04)], flips


def test_database_adapt_round_trip():
    """Database.adapt under drifting density keeps relation contents and
    respects the hysteresis (engine-level wiring of adapt_value)."""
    schema = ir.Schema()
    schema.declare("E", ("id", "id"), "bool")
    base = _dense_at_density("bool", 0.02, shape=(16, 16))
    db = engine.Database(schema, {"id": 16}, {"E": base})
    db1 = db.adapt()
    assert db1.storage_of("E") == "sparse"
    assert np.array_equal(_contents(db1.relations["E"], "bool"), base)
    db2 = db1.adapt()
    assert db2.storage_of("E") == "sparse"  # stable under re-adaptation
    dense_again = db2.with_storage("E", "dense")
    assert np.array_equal(np.asarray(dense_again.relations["E"]), base)
