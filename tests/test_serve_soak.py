"""Randomized soak of the continuous scheduler: hundreds of interleaved
queries, edge mutations, and backpressure bursts across two families,
checked against independent host oracles (BFS / Bellman-Ford) and — on
sampled requests — the offline engine itself.

Invariants exercised per ISSUE 6:
* no accepted request is lost or delivered twice;
* per family, answers (and update acknowledgements) are delivered in
  submission order, no matter how far out of order rows converged;
* every answer equals the offline single-source fixpoint **against the
  graph version in force when the request was submitted** (the update
  fence), including warm-cache hits and delta-repaired answers;
* shed requests (queue at bound) raise and are never partially served.
"""

import numpy as np
import pytest

import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from helpers import given, settings, strategies as st

from repro.core import engine
from repro.core.program import run_program
from repro.datalog import datasets, programs
from repro.serve import BackpressureError, ContinuousServer
from repro.serve.family import QueryRequest, UpdateRequest


def _bfs(n, edge_set, source):
    """Boolean reachability oracle over a python edge set."""
    adj = {}
    for u, v in edge_set:
        adj.setdefault(u, []).append(v)
    seen = np.zeros(n, bool)
    seen[source] = True
    frontier = [source]
    while frontier:
        nxt = []
        for u in frontier:
            for v in adj.get(u, ()):
                if not seen[v]:
                    seen[v] = True
                    nxt.append(v)
        frontier = nxt
    return seen


def _bellman_ford(n, wedges, source):
    """Min-plus distance oracle (float32, inf = unreachable)."""
    dist = np.full(n, np.inf, np.float32)
    dist[source] = 0.0
    for _ in range(n):
        changed = False
        for (u, v), w in wedges.items():
            nd = dist[u] + w
            if nd < dist[v]:
                dist[v] = np.float32(nd)
                changed = True
        if not changed:
            break
    return dist


@settings(max_examples=2, deadline=None)
@given(seed=st.integers(0, 2**20), chunk_iters=st.sampled_from([1, 2, 4]),
       host_kernels=st.booleans())
def test_soak_continuous_scheduler(seed, chunk_iters, host_kernels):
    rng = np.random.default_rng(seed)
    n_bm, n_ss = 60, 50

    g_bm = datasets.erdos_renyi(n_bm, 2.5, seed=seed % 97)
    schema = programs.bm(a=0).original.schema
    db_bm = engine.Database(
        schema, {"id": n_bm},
        {"E": g_bm.sparse_adjacency(), "V": jnp.ones((n_bm,), bool)})

    g_ss = datasets.erdos_renyi(n_ss, 3.0, seed=(seed + 1) % 89,
                                weighted=True, wmax=4)
    mk_ss = lambda a: programs.sssp(a=a, wmax=4, dmax=48).optimized
    db_ss = programs.sssp(a=0, wmax=4, dmax=48).make_db(g_ss)

    cs = ContinuousServer(max_batch=8, chunk_iters=chunk_iters,
                          queue_limit=16, warm_answers=32,
                          host_kernels=host_kernels)
    cs.register("reach", lambda a: programs.bm(a=a).optimized, db_bm,
                weight=2)
    cs.register("sssp", mk_ss, db_ss,
                edges=g_ss.sparse_adjacency(semiring="trop"))

    # graph-version bookkeeping for the reach family (updates target it
    # exclusively); version v's edge set feeds the BFS oracle
    eh = g_bm.sparse_adjacency().as_np()
    edge_sets = [{(int(u), int(v))
                  for u, v in np.asarray(eh.coords[:int(eh.nnz)])}]
    wedges_ss = {(int(u), int(v)): float(w) for (u, v), w in
                 zip(g_ss.edges, g_ss.weights)}

    accepted = []        # (request, family, version-at-submission)
    updates = []
    delivered = []
    shed = 0

    def submit_reach(source):
        nonlocal shed
        try:
            req = cs.submit("reach", source)
        except BackpressureError:
            shed += 1
            return
        accepted.append((req, "reach", len(edge_sets) - 1))

    def submit_sssp(source):
        nonlocal shed
        try:
            req = cs.submit("sssp", source)
        except BackpressureError:
            shed += 1
            return
        accepted.append((req, "sssp", 0))

    n_events = 300
    for i in range(n_events):
        roll = rng.random()
        if roll < 0.45:
            submit_reach(int(rng.integers(0, n_bm)))
        elif roll < 0.80:
            submit_sssp(int(rng.integers(0, n_ss)))
        elif roll < 0.88 and len(edge_sets) <= 5:
            cur = edge_sets[-1]
            if roll < 0.84 or not cur:       # merge a fresh random edge
                u, v = (int(x) for x in rng.integers(0, n_bm, 2))
                if u == v:
                    v = (v + 1) % n_bm
                updates.append(cs.submit_update("reach", [[u, v]]))
                edge_sets.append(cur | {(u, v)})
            else:                            # delete an existing edge
                u, v = list(cur)[int(rng.integers(0, len(cur)))]
                updates.append(
                    cs.submit_update("reach", [[u, v]], op="delete"))
                edge_sets.append(cur - {(u, v)})
            accepted.append((updates[-1], "reach", len(edge_sets) - 1))
        elif roll < 0.93:
            # burst: slam the queue past its bound to force shedding
            for _ in range(25):
                submit_reach(int(rng.integers(0, n_bm)))
        else:
            delivered.extend(cs.step())
        if rng.random() < 0.3:
            delivered.extend(cs.step())
    while cs.pending():
        delivered.extend(cs.step())

    st_ = cs.stats()
    assert shed == st_["shed"] and shed > 0, \
        "bursts must force backpressure for this soak to mean anything"

    # --- no loss, no duplication -------------------------------------------
    ids = [id(r) for r in delivered]
    assert len(ids) == len(set(ids)), "a request was delivered twice"
    assert len(delivered) == len(accepted), \
        f"{len(accepted)} accepted but {len(delivered)} delivered"
    for req, _, _ in accepted:
        assert req.done_s > 0.0, "an accepted request was never finished"

    # --- FIFO-per-family delivery ------------------------------------------
    for fam_name in ("reach", "sssp"):
        sub_order = [r for r, f, _ in accepted if f == fam_name]
        del_order = [r for r in delivered
                     if (r.family if isinstance(r, QueryRequest)
                         else r.family) == fam_name]
        assert del_order == sub_order, \
            f"{fam_name}: delivery order diverged from submission order"

    # --- every update applied ----------------------------------------------
    for u in updates:
        assert u.applied and u.error is None, u.error

    # --- exactness against the version in force at submission --------------
    reach_oracle = {}
    for req, fam_name, version in accepted:
        if isinstance(req, UpdateRequest):
            continue
        assert req.error is None, req.error
        got = np.asarray(req.result)
        if fam_name == "reach":
            key = (version, req.source)
            if key not in reach_oracle:
                reach_oracle[key] = _bfs(n_bm, edge_sets[version],
                                         req.source)
            assert np.array_equal(got, reach_oracle[key]), \
                (req.source, version)
        else:
            assert np.array_equal(
                got, _bellman_ford(n_ss, wedges_ss, req.source)), \
                req.source

    # --- the host oracles agree with the offline engine (sampled) ----------
    final_db = engine.Database(
        schema, {"id": n_bm},
        {"E": _edges_rel(n_bm, edge_sets[-1]),
         "V": jnp.ones((n_bm,), bool)})
    for s in rng.integers(0, n_bm, 3):
        ans, _ = run_program(programs.bm(a=int(s)).optimized, final_db,
                             mode="seminaive")
        assert np.array_equal(np.asarray(ans),
                              _bfs(n_bm, edge_sets[-1], int(s)))
    for s in rng.integers(0, n_ss, 2):
        ans, _ = run_program(mk_ss(int(s)), db_ss, mode="seminaive")
        assert np.array_equal(np.asarray(ans),
                              _bellman_ford(n_ss, wedges_ss, int(s)))


def _edges_rel(n, edge_set):
    from repro.sparse import SparseRelation
    if not edge_set:
        coords = np.zeros((0, 2), np.int64)
    else:
        coords = np.asarray(sorted(edge_set), np.int64)
    return SparseRelation.from_coo(
        coords, np.ones(len(coords), bool), (n, n), "bool")
