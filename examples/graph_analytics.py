"""Graph-analytics workload suite on the Datalog° engine.

  PYTHONPATH=src python examples/graph_analytics.py

Optimizes and runs SSSP, MLM (tree aggregation), and Window-Sum — the
paper's CEGIS group — shows generalized semi-naive (GSN) execution of
the optimized single-source program, and finishes with batched
multi-source serving: many (source, query) requests answered by one
SpMM-stepped fixpoint through `launch.datalog_serve` (DESIGN.md §3).
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import engine, fgh, ir, verify
from repro.core.program import run_program
from repro.datalog import datasets, programs


def optimize_and_run(name, bench, edbs, db, mode="naive"):
    task = verify.task_from_program(bench.original, edbs,
                                    constraint=bench.constraint)
    rep = fgh.optimize(task, rng=np.random.default_rng(0))
    assert rep.ok, name
    if bench.original.post is not None:
        rep.program.post = bench.original.post
    t0 = time.perf_counter()
    a1, _ = run_program(bench.original, db)
    t1 = time.perf_counter() - t0
    t0 = time.perf_counter()
    a2, _ = run_program(rep.program, db, mode=mode)
    t2 = time.perf_counter() - t0
    ok = np.allclose(np.asarray(a1, np.float32), np.asarray(a2, np.float32),
                     equal_nan=True, atol=1e-3)
    print(f"{name:8s} method={rep.method:5s} mode={mode:9s} "
          f"orig {t1*1e3:7.0f} ms  opt {t2*1e3:7.0f} ms  "
          f"speedup {t1/t2:6.1f}x  equal={bool(ok)}")
    return rep


def main():
    print("== SSSP (weighted ER graph), naive + GSN ==")
    b = programs.sssp(a=0, wmax=4, dmax=48)
    g = datasets.erdos_renyi(128, 4.0, seed=1, weighted=True, wmax=4)
    db = b.make_db(g)
    optimize_and_run("SSSP", b, ["E3"], db)
    optimize_and_run("SSSP", b, ["E3"], db, mode="seminaive")

    print("\n== MLM (multi-level marketing, tree constraint Γ) ==")
    b = programs.mlm()
    g = datasets.decay_tree(128, seed=2)
    print(f"   tree depth {datasets.tree_depth(g)}")
    optimize_and_run("MLM", b, ["E", "V"], b.make_db(g))

    print("\n== WS (sliding window sum) ==")
    b = programs.ws(window=10, vmax=6)
    optimize_and_run("WS", b, ["A2"],
                     b.make_db(datasets.vector_data(160, seed=0, vmax=6)))

    batched_queries()


def batched_queries(n: int = 4000, requests: int = 128,
                    max_batch: int = 32):
    """Batched multi-source serving: the FGH-optimized reachability
    program answered for many different sources at once.  The serve loop
    packs queued (family, source) requests, evaluates only the O(n) init
    per request, and advances the whole pack in one SpMM-stepped
    ``lax.while_loop`` — compare the per-source loop it replaces."""
    import jax
    import jax.numpy as jnp

    from repro.launch.datalog_serve import DatalogServer
    from repro.sparse import sparse_seminaive_fixpoint

    print("\n== Batched multi-source serving (reachability) ==")
    g = datasets.powerlaw(n, 4, seed=0)
    rel = g.sparse_adjacency().as_jnp()
    schema = programs.bm(a=0).original.schema
    db = engine.Database(schema, {"id": n},
                         {"E": rel, "V": jnp.ones((n,), bool)})
    server = DatalogServer(max_batch=max_batch)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)

    rng = np.random.default_rng(0)
    sources = [int(s) for s in rng.integers(0, n, requests)]
    reqs = [server.submit("reach", s) for s in sources]
    server.run_until_idle()          # warm the compile cache
    reqs = [server.submit("reach", s) for s in sources]
    t0 = time.perf_counter()
    server.run_until_idle()
    t_batch = time.perf_counter() - t0

    single = jax.jit(lambda e, i: sparse_seminaive_fixpoint(e, i,
                                                            mode="jit"))
    init0 = np.zeros(n, bool)
    init0[sources[0]] = True
    jax.block_until_ready(single(rel, jnp.asarray(init0))[0])  # warm
    t0 = time.perf_counter()
    loop = {}
    for s in dict.fromkeys(sources):
        init = np.zeros(n, bool)
        init[s] = True
        loop[s], _ = single(rel, jnp.asarray(init))
    t_loop = time.perf_counter() - t0
    ok = all(np.array_equal(r.result, np.asarray(loop[r.source]))
             for r in reqs)
    print(f"{requests} requests over {len(loop)} distinct sources, "
          f"n={n}: batched {requests / t_batch:7.1f} qps   "
          f"per-source loop {len(loop) / t_loop:7.1f} qps   "
          f"equal={ok}")
    print(f"server stats: {server.stats}")


if __name__ == "__main__":
    main()
