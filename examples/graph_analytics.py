"""Graph-analytics workload suite on the Datalog° engine.

  PYTHONPATH=src python examples/graph_analytics.py

Optimizes and runs SSSP, MLM (tree aggregation), and Window-Sum — the
paper's CEGIS group — and shows generalized semi-naive (GSN) execution of
the optimized single-source program.
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import fgh, ir, verify
from repro.core.program import run_program
from repro.datalog import datasets, programs


def optimize_and_run(name, bench, edbs, db, mode="naive"):
    task = verify.task_from_program(bench.original, edbs,
                                    constraint=bench.constraint)
    rep = fgh.optimize(task, rng=np.random.default_rng(0))
    assert rep.ok, name
    if bench.original.post is not None:
        rep.program.post = bench.original.post
    t0 = time.perf_counter()
    a1, _ = run_program(bench.original, db)
    t1 = time.perf_counter() - t0
    t0 = time.perf_counter()
    a2, _ = run_program(rep.program, db, mode=mode)
    t2 = time.perf_counter() - t0
    ok = np.allclose(np.asarray(a1, np.float32), np.asarray(a2, np.float32),
                     equal_nan=True, atol=1e-3)
    print(f"{name:8s} method={rep.method:5s} mode={mode:9s} "
          f"orig {t1*1e3:7.0f} ms  opt {t2*1e3:7.0f} ms  "
          f"speedup {t1/t2:6.1f}x  equal={bool(ok)}")
    return rep


def main():
    print("== SSSP (weighted ER graph), naive + GSN ==")
    b = programs.sssp(a=0, wmax=4, dmax=48)
    g = datasets.erdos_renyi(128, 4.0, seed=1, weighted=True, wmax=4)
    db = b.make_db(g)
    optimize_and_run("SSSP", b, ["E3"], db)
    optimize_and_run("SSSP", b, ["E3"], db, mode="seminaive")

    print("\n== MLM (multi-level marketing, tree constraint Γ) ==")
    b = programs.mlm()
    g = datasets.decay_tree(128, seed=2)
    print(f"   tree depth {datasets.tree_depth(g)}")
    optimize_and_run("MLM", b, ["E", "V"], b.make_db(g))

    print("\n== WS (sliding window sum) ==")
    b = programs.ws(window=10, vmax=6)
    optimize_and_run("WS", b, ["A2"],
                     b.make_db(datasets.vector_data(160, seed=0, vmax=6)))


if __name__ == "__main__":
    main()
