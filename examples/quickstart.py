"""Quickstart: FGH-optimize connected components (paper Fig. 1) end-to-end.

  PYTHONPATH=src python examples/quickstart.py

1. defines Π₁ — transitive closure + min-label aggregation (Fig. 1a),
2. runs the FGH optimizer (invariant inference → rule-based denormalization
   → verification) to synthesize H (Fig. 1b),
3. executes both programs on a power-law graph and compares answers+time.
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import fgh, ir, verify
from repro.core.program import run_program
from repro.datalog import datasets, programs


def main():
    bench = programs.cc()
    print("Π₁ (original, Fig. 1a):")
    for name, rule in bench.original.strata[0].rules.items():
        print(f"  {name}{ir.ssp_str(rule.body)}")
    for out in bench.original.outputs:
        print(f"  {out.head}{ir.ssp_str(out.body)}")

    task = verify.task_from_program(bench.original, ["E", "V"])
    rep = fgh.optimize(task, rng=np.random.default_rng(0))
    assert rep.ok
    print(f"\nsynthesized H via {rep.method} in "
          f"{rep.stats['total_time_s']:.3f}s "
          f"(invariants mined: {len(rep.invariants)}):")
    print(f"  CC{ir.ssp_str(rep.h_body)}")

    g = datasets.powerlaw(600, m_attach=3, seed=0)
    db = bench.make_db(g)
    t0 = time.perf_counter()
    ans1, s1 = run_program(bench.original, db)
    t1 = time.perf_counter() - t0
    t0 = time.perf_counter()
    ans2, s2 = run_program(rep.program, db)
    t2 = time.perf_counter() - t0
    same = bool(np.allclose(np.asarray(ans1), np.asarray(ans2),
                            equal_nan=True))
    print(f"\nn={g.n}: original {t1*1e3:.0f} ms ({s1.iterations[0]} iters, "
          f"O(n²) state) vs optimized {t2*1e3:.0f} ms "
          f"({s2.iterations[0]} iters, O(n) state)")
    print(f"answers equal: {same}   speedup: {t1/t2:.1f}x")


if __name__ == "__main__":
    main()
