"""Batched serving example: prefill + continuous greedy decode.

  PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b --batch 4
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro import configs
from repro.launch.serve import Request, serve_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=True)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab, args.prompt_len,
                                 dtype=np.int32), args.max_new)
            for _ in range(args.batch)]
    stats = serve_batch(args.arch, reqs, smoke=True, t_max=128)
    print(f"arch={args.arch} (smoke config, {cfg.family})")
    print(f"prefill: {stats['prefill_s']*1e3:.0f} ms for batch "
          f"{args.batch} × {args.prompt_len} tokens")
    print(f"decode:  {stats['tok_per_s']:.1f} tok/s")
    for i, r in enumerate(reqs):
        print(f"  req{i}: {r.out}")


if __name__ == "__main__":
    main()
