"""End-to-end LM training driver (deliverable (b)): train the xLSTM-125M
architecture (full published config, ~100M params) for a few hundred steps
on the synthetic pipeline, with checkpointing and WSD/cosine scheduling.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]

On this CPU container the default uses a shortened sequence length; pass
--full --seq 1024 on real hardware.
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="train the full published config (CPU: slow)")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    params, losses = train(args.arch, steps=args.steps, batch=args.batch,
                           seq=args.seq, smoke=not args.full,
                           ckpt_dir=args.ckpt, log_every=20)
    print(f"\nloss: {losses[0]:.3f} → {losses[-1]:.3f} over "
          f"{len(losses)} steps")
    assert losses[-1] < losses[0], "training failed to reduce loss"


if __name__ == "__main__":
    main()
