# Tier-1 verification and benchmark entry points.
#
#   make test        — fast tier-1 suite (slow-marked tests excluded)
#   make test-all    — everything, including AOT dry-run compiles
#   make lint        — ruff check + format check (no-op if ruff missing)
#   make bench-smoke — small-size pass over the benchmark drivers
#   make bench-sparse— dense-vs-sparse scaling acceptance run
#   make bench-serve — batched serving throughput (writes BENCH_serve.json)
#   make bench-plan  — planner-vs-empirical crossover smoke (CI gate;
#                      exits 1 on disagreement at the extremes)
#   make bench-incremental — streaming-update maintenance acceptance
#                      (CI gate; exits 1 below the ≥10× update-to-answer
#                      speedup, on answer divergence, or when the planner
#                      fails to pick delta_restart; BENCH_incremental.json)
#   make test-dist   — the sharded suite on 8 simulated host devices
#                      (DESIGN.md §6; CI job test-distributed)
#   make bench-sharded — graph-axis sharded crossover acceptance on 8
#                      simulated devices (CI gate; exits 1 on
#                      sharded/single-device divergence, when D=8 loses
#                      to one device at the largest size, when exchanged
#                      bytes drop < 5× under the dense all-gather, or
#                      when the planner's pick disagrees with the
#                      measured winner on either side of the crossover;
#                      BENCH_sharded.json)
#   make bench-check — regression gate: fresh BENCH_*.json vs the
#                      committed baselines (exits 1 on >25% regression;
#                      the unitless sharded speedup gets a tighter 20%
#                      gate so the crossover claim cannot quietly rot)
#   make bench-kernel — fused SpMM vs jnp sweep (semiring × B × density;
#                      CI gate: exits 1 below the 1.5× bool B=64 serve-
#                      shape floor or on kernel/oracle divergence;
#                      BENCH_kernels.json) + the measured roofline
#                      (results/roofline.json).  REPRO_PALLAS_INTERPRET
#                      routes dispatch-level ops through the Pallas
#                      kernels in interpret mode; the perf sweep always
#                      times the hardware backend.
#   make test-kernel — fast fused-kernel parity suite in Pallas
#                      interpret mode (CI test matrix step)
#   make docs-check  — docs gate (CI lint step): every §N pointer in
#                      the tree resolves to a DESIGN.md section and
#                      every README ```python example executes

PY      ?= python
PYPATH  := src
DIST_FLAGS := --xla_force_host_platform_device_count=8

test:
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -x -q

test-all:
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -q -m "slow or not slow" --durations=20

test-dist:
	XLA_FLAGS=$(DIST_FLAGS) PYTHONPATH=$(PYPATH) $(PY) -m pytest -x -q tests/test_sharded.py

# Format-check only files changed since origin/main (or HEAD~1): the
# tree predates ruff-format, so a blanket --check fails on files the
# change never touched — same scoping as the CI lint job.
lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check . || exit 1; \
		BASE=$$(git merge-base origin/main HEAD 2>/dev/null \
			|| git rev-parse HEAD~1 2>/dev/null \
			|| git rev-parse HEAD); \
		CHANGED=$$(git diff --name-only --diff-filter=ACMR "$$BASE" -- '*.py'); \
		if [ -z "$$CHANGED" ]; then \
			echo "no Python files changed — format check skipped"; \
		else \
			echo "$$CHANGED" | xargs ruff format --check; \
		fi; \
	else \
		echo "ruff not installed; skipping lint"; \
	fi

bench-smoke:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run --quick --only sparse,serve,kernel,plan,incremental,sharded,replan

bench-sparse:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.sparse_scaling

bench-serve:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.serve_batch

bench-plan:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.plan_crossover --quick

bench-incremental:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.incremental_update

bench-sharded:
	XLA_FLAGS=$(DIST_FLAGS) PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.sharded_scaling

bench-replan:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.replan_adaptive

bench-check:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.check_regression \
		--metric-threshold speedup=0.2

bench-kernel:
	REPRO_PALLAS_INTERPRET=1 PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.kernel_bench
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.roofline

test-kernel:
	REPRO_PALLAS_INTERPRET=1 PYTHONPATH=$(PYPATH) $(PY) -m pytest -x -q tests/test_coo_spmm.py

docs-check:
	PYTHONPATH=$(PYPATH) $(PY) tools/docs_check.py

.PHONY: test test-all test-dist lint bench-smoke bench-sparse \
	bench-serve bench-plan bench-incremental bench-sharded bench-replan \
	bench-check bench-kernel test-kernel docs-check
