# Tier-1 verification and benchmark entry points.
#
#   make test        — fast tier-1 suite (slow-marked tests excluded)
#   make test-all    — everything, including AOT dry-run compiles
#   make bench-smoke — small-size pass over the benchmark drivers
#   make bench-sparse— dense-vs-sparse scaling acceptance run
#   make bench-serve — batched serving throughput (writes BENCH_serve.json)
#   make bench-plan  — planner-vs-empirical crossover smoke (CI gate;
#                      exits 1 on disagreement at the extremes)

PY      ?= python
PYPATH  := src

test:
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -x -q

test-all:
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -q -m "slow or not slow"

bench-smoke:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run --quick --only sparse,serve,kernel

bench-sparse:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.sparse_scaling

bench-serve:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.serve_batch

bench-plan:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.plan_crossover --quick

.PHONY: test test-all bench-smoke bench-sparse bench-serve bench-plan
