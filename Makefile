# Tier-1 verification and benchmark entry points.
#
#   make test        — fast tier-1 suite (slow-marked tests excluded)
#   make test-all    — everything, including AOT dry-run compiles
#   make lint        — ruff check + format check (no-op if ruff missing)
#   make bench-smoke — small-size pass over the benchmark drivers
#   make bench-sparse— dense-vs-sparse scaling acceptance run
#   make bench-serve — batched serving throughput (writes BENCH_serve.json)
#   make bench-plan  — planner-vs-empirical crossover smoke (CI gate;
#                      exits 1 on disagreement at the extremes)
#   make bench-incremental — streaming-update maintenance acceptance
#                      (CI gate; exits 1 below the ≥10× update-to-answer
#                      speedup, on answer divergence, or when the planner
#                      fails to pick delta_restart; BENCH_incremental.json)

PY      ?= python
PYPATH  := src

test:
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -x -q

test-all:
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -q -m "slow or not slow" --durations=20

lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check . && ruff format --check .; \
	else \
		echo "ruff not installed; skipping lint"; \
	fi

bench-smoke:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run --quick --only sparse,serve,kernel,plan,incremental

bench-sparse:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.sparse_scaling

bench-serve:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.serve_batch

bench-plan:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.plan_crossover --quick

bench-incremental:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.incremental_update

.PHONY: test test-all lint bench-smoke bench-sparse bench-serve \
	bench-plan bench-incremental
