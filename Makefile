# Tier-1 verification and benchmark entry points.
#
#   make test        — fast tier-1 suite (slow-marked tests excluded)
#   make test-all    — everything, including AOT dry-run compiles
#   make bench-smoke — small-size pass over the benchmark drivers
#   make bench-sparse— dense-vs-sparse scaling acceptance run

PY      ?= python
PYPATH  := src

test:
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -x -q

test-all:
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -q -m "slow or not slow"

bench-smoke:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.sparse_scaling --sizes 256,512 --big 2000
	PYTHONPATH=$(PYPATH) $(PY) -c "from benchmarks import kernel_bench; kernel_bench.run(sizes=(128,), semirings=('bool', 'trop'))"

bench-sparse:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.sparse_scaling

.PHONY: test test-all bench-smoke bench-sparse
